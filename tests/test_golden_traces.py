"""Golden-trace regression suite.

One tiny recorded trace per scenario preset lives in ``tests/golden/``.
Three guarantees per preset:

  * replaying the stored trace reproduces its recorded ledger totals
    (the stored MPG composition) *exactly* — bit-for-bit, no approx;
  * re-simulating the preset at the golden configuration produces a
    byte-identical trace — any simulator behaviour change trips this,
    and an intentional change is blessed via
    ``python -m repro.fleet.trace --refresh-golden``;
  * the same seed run twice in-process yields identical bytes (the
    determinism-audit contract: no shared random-module state, no
    dict-order dependence, no wall-clock reads in the sim path).
"""
import pathlib

import pytest

from repro.core.goodput import GoodputReport
from repro.fleet.scenarios import SCENARIOS, golden_sim
from repro.fleet.trace import TRACE_VERSION, Trace, record, replay, verify

GOLDEN = pathlib.Path(__file__).parent / "golden"
PRESETS = sorted(SCENARIOS)


def test_every_preset_has_a_golden_trace():
    missing = [p for p in PRESETS if not (GOLDEN / f"{p}.jsonl").exists()]
    assert not missing, (
        f"no golden trace for preset(s) {missing}; run "
        "`PYTHONPATH=src python -m repro.fleet.trace --refresh-golden`")
    stray = sorted(f.stem for f in GOLDEN.glob("*.jsonl")
                   if f.stem not in PRESETS)
    assert not stray, f"golden trace(s) without a preset: {stray}"


@pytest.mark.parametrize("preset", PRESETS)
def test_replay_reproduces_recorded_totals_exactly(preset):
    trace = Trace.load(GOLDEN / f"{preset}.jsonl")
    assert trace.version == TRACE_VERSION
    assert trace.meta["scenario"] == preset
    replayed = replay(trace)
    # plain equality: every float must reproduce bit-for-bit
    assert replayed.totals() == trace.totals
    verify(trace)   # the CLI-facing check agrees


@pytest.mark.parametrize("engine", ["reference", "vectorized"])
@pytest.mark.parametrize("preset", PRESETS)
def test_resimulated_trace_is_byte_identical(preset, engine):
    # the equivalence gate: BOTH event cores must reproduce the stored
    # bytes, so golden traces pin the engines to each other as well as
    # to history — no speed claim counts unless this passes
    stored = (GOLDEN / f"{preset}.jsonl").read_text()
    fresh = record(golden_sim(preset, engine=engine)).dumps()
    assert fresh == stored, (
        f"simulator behaviour changed for preset {preset!r} "
        f"(engine={engine!r}); if intentional, refresh with "
        "`PYTHONPATH=src python -m repro.fleet.trace --refresh-golden`")


@pytest.mark.parametrize("engine", ["reference", "vectorized"])
def test_same_seed_twice_is_identical(engine):
    a = record(golden_sim("peak_week", engine=engine)).dumps()
    b = record(golden_sim("peak_week", engine=engine)).dumps()
    assert a == b


@pytest.mark.parametrize("preset", PRESETS)
def test_golden_mpg_composition_is_physical(preset):
    trace = Trace.load(GOLDEN / f"{preset}.jsonl")
    rep = replay(trace).report()
    assert isinstance(rep, GoodputReport)
    for v in (rep.sg, rep.rg, rep.pg, rep.mpg):
        assert 0.0 <= v <= 1.0
    assert trace.totals["n_events"] == len(trace.events)


def test_trace_roundtrip_and_version_gate(tmp_path):
    trace = Trace.load(GOLDEN / "steady.jsonl")
    text = trace.dumps()
    assert Trace.loads(text).dumps() == text
    p = trace.dump(tmp_path / "t.jsonl")
    assert Trace.load(p).dumps() == text
    bumped = text.replace('"version":2', '"version":99', 1)
    with pytest.raises(ValueError, match="version"):
        Trace.loads(bumped)


def test_record_refuses_a_used_ledger():
    sim = golden_sim("steady")
    sim.run()
    with pytest.raises(ValueError, match="before any event"):
        record(sim)
