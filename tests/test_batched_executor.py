"""Batched paged-decode executor: token identity vs the per-slot
executor (dense + MoE), zero recompilation across admission/detach, and
the paged model path's logits equivalence (ref impl vs Pallas kernel in
interpret mode)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import model
from repro.serve.batched_executor import JaxBatchedExecutor, make_executor
from repro.serve.engine import NO_SLO, ContinuousServeEngine, ServeRequest
from repro.serve.jax_executor import JaxSlotExecutor

MAX_LEN = 32


def _requests(cfg, n=10, seed=7):
    """Mixed prompt lengths and output budgets, all within MAX_LEN."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, 13))
        reqs.append(ServeRequest(
            rid=i, prompt_len=plen, max_new=1 + i % 5, t_submit=0.0,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32)))
    return reqs


def _serve(cfg, batched: bool, n_slots=4, **ex_kw):
    reqs = _requests(cfg)
    if batched:
        ex = JaxBatchedExecutor(cfg, MAX_LEN, n_slots, **ex_kw)
        eng = ContinuousServeEngine(n_slots, ex, slo=NO_SLO, kv_cache=ex.kv)
    else:
        ex = JaxSlotExecutor(cfg, MAX_LEN)
        eng = ContinuousServeEngine(n_slots, ex, slo=NO_SLO)
    eng.run(reqs)
    return {r.rid: list(r.out_tokens) for r in reqs}, ex


@pytest.mark.parametrize("arch", ["smollm-135m", "deepseek-moe-16b"])
def test_batched_token_identical_to_per_slot(arch):
    """The acceptance property: one jitted decode at fixed width serves
    mixed-length live slots token-identically to per-slot batch-1 decode
    — on the dense AND the MoE config."""
    cfg = get_smoke(arch)
    per_slot, _ = _serve(cfg, batched=False)
    batched, ex = _serve(cfg, batched=True, attn_impl="ref")
    assert batched == per_slot
    assert sum(len(v) for v in batched.values()) > 0


def test_admission_detach_zero_recompilation():
    """10 requests with 8 distinct lengths churn through 4 rows — the
    batched decode must compile exactly once (the compile-count probe)."""
    cfg = get_smoke("smollm-135m")
    _, ex = _serve(cfg, batched=True, attn_impl="ref")
    assert ex.decode_compiles() == 1


def test_paged_step_kernel_matches_ref_logits():
    """The Pallas kernel (interpret mode — the real code path CI runs)
    and the XLA gather ref produce the same logits inside the full
    jitted model step."""
    cfg = get_smoke("smollm-135m")
    ex = JaxBatchedExecutor(cfg, MAX_LEN, 3, attn_impl="ref")
    # occupy rows with mixed lengths via a real engine run prefix
    reqs = _requests(cfg, n=3)
    for r in reqs:
        ex.kv.allocate(r.rid, r.prompt_len)
    ex.prefill(reqs)
    for r in reqs:
        ex.kv.append_token(r.rid)
        row = ex.rows[r.rid]
        ex._len[row] = ex.kv.seq_len(r.rid)
        table = ex.kv.block_table(r.rid)
        ex._tables[row, :len(table)] = table
    tok = jnp.asarray(ex._tok)
    lens = jnp.asarray(ex._len)
    bt = jnp.asarray(ex._tables)
    out = {}
    for impl in ("ref", "kernel"):
        step = model.paged_decode_fn(cfg, attn_impl=impl, interpret=True)
        logits, _, _ = step(ex.params, tok, lens, ex._kp, ex._vp, bt)
        out[impl] = np.asarray(logits)
    np.testing.assert_allclose(out["kernel"], out["ref"], atol=1e-4)
    assert np.array_equal(out["kernel"].argmax(-1), out["ref"].argmax(-1))


def test_make_executor_falls_back_for_unpaged_families():
    cfg = get_smoke("rwkv6-3b")
    assert not model.supports_paged_decode(cfg, MAX_LEN)
    ex, kv = make_executor(cfg, MAX_LEN, 2)
    assert isinstance(ex, JaxSlotExecutor) and kv is None

    dense = get_smoke("smollm-135m")
    ex2, kv2 = make_executor(dense, MAX_LEN, 2)
    assert isinstance(ex2, JaxBatchedExecutor) and kv2 is ex2.kv


def test_windowed_config_rejected():
    """A sliding window narrower than max_len trims the prefill cache, so
    the paged path must refuse rather than serve wrong prefixes."""
    cfg = get_smoke("smollm-135m")
    windowed = dataclasses.replace(cfg, attention_window=8)
    assert not model.supports_paged_decode(windowed, MAX_LEN)
    with pytest.raises(ValueError, match="paged"):
        JaxBatchedExecutor(windowed, MAX_LEN, 2)
    # window >= max_len masks nothing — paged decode stays exact
    wide = dataclasses.replace(cfg, attention_window=MAX_LEN)
    assert model.supports_paged_decode(wide, MAX_LEN)


def test_rows_recycle_and_release():
    cfg = get_smoke("smollm-135m")
    ex = JaxBatchedExecutor(cfg, MAX_LEN, 2)
    reqs = _requests(cfg, n=2)
    for r in reqs:
        ex.kv.allocate(r.rid, r.prompt_len)
    ex.prefill(reqs)
    assert len(ex.rows) == 2 and not ex._free_rows
    ex.kv.free(reqs[0].rid)
    ex.release(reqs[0])
    assert len(ex.rows) == 1 and len(ex._free_rows) == 1
    row = 1 - ex.rows[reqs[1].rid]
    assert ex._len[row] == 0
    assert np.all(ex._tables[row] == ex.null_page)
