"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
pure-jnp (or fp64 numpy) oracles (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # property tests skip, the rest still run
    from tests._hypothesis_fallback import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention_bshd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm.ops import moe_gmm
from repro.kernels.moe_gmm.ref import moe_gmm_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rwkv6_wkv.ops import rwkv6_wkv
from repro.kernels.rwkv6_wkv.ref import wkv_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (2, 4, 2, 64, 32), (1, 8, 1, 128, 16), (2, 2, 2, 32, 64),
    (1, 6, 3, 96, 32),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
def test_flash_attention_shapes(b, hq, hkv, s, d, causal, window):
    ks = jax.random.split(jax.random.key(b * s + d), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    out = flash_attention_bshd(q, k, v, causal=causal, window=window,
                               block_q=32, block_k=32, interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=causal,
                        window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, ref, atol=3e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5),
                                        (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, atol):
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 64, 2, 32)).astype(dtype)
    out = flash_attention_bshd(q, k, v, block_q=32, block_k=32,
                               interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    assert out.dtype == dtype
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), atol=atol)


@settings(max_examples=10, deadline=None)
@given(bq=st.sampled_from([16, 32, 64]), bk=st.sampled_from([16, 32, 64]),
       window=st.sampled_from([0, 8, 24, 100]))
def test_flash_attention_block_invariance(bq, bk, window):
    """Property: output is independent of kernel block sizes."""
    ks = jax.random.split(jax.random.key(99), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.float32)
    out = flash_attention_bshd(q, k, v, window=window, block_q=bq,
                               block_k=bk, interpret=True)
    base = flash_attention_bshd(q, k, v, window=window, block_q=64,
                                block_k=64, interpret=True)
    np.testing.assert_allclose(out, base, atol=3e-5)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,c,bs_,bc", [
    (2, 64, 32, 16, 16), (1, 128, 64, 32, 32), (3, 96, 48, 96, 16),
    (1, 256, 128, 64, 128),
])
def test_rglru_scan_shapes(b, s, c, bs_, bc):
    ks = jax.random.split(jax.random.key(s + c), 2)
    a = jax.random.uniform(ks[0], (b, s, c), minval=0.85, maxval=0.999)
    x = jax.random.normal(ks[1], (b, s, c)) * 0.1
    out = rglru_scan(a, x, block_s=bs_, block_c=bc, interpret=True)
    np.testing.assert_allclose(out, rglru_scan_ref(a, x), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_rglru_scan_property(seed):
    """Property: result equals the sequential recurrence for random inputs."""
    ks = jax.random.split(jax.random.key(seed), 2)
    a = jax.random.uniform(ks[0], (1, 32, 16), minval=0.0, maxval=1.0)
    x = jax.random.normal(ks[1], (1, 32, 16))
    out = rglru_scan(a, x, block_s=8, block_c=8, interpret=True)
    h = np.zeros((1, 16))
    want = np.zeros((1, 32, 16))
    an, xn = np.asarray(a), np.asarray(x)
    for t in range(32):
        h = an[:, t] * h + xn[:, t]
        want[:, t] = h
    np.testing.assert_allclose(out, want, atol=1e-5)


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,n,chunk", [
    (1, 2, 32, 16, 8), (2, 1, 64, 32, 16), (1, 3, 48, 16, 48),
    (1, 1, 128, 64, 32),
])
def test_rwkv6_wkv_shapes(b, h, s, n, chunk):
    ks = jax.random.split(jax.random.key(s + n), 5)
    r = jax.random.normal(ks[0], (b, h, s, n)) * 0.5
    k = jax.random.normal(ks[1], (b, h, s, n)) * 0.5
    v = jax.random.normal(ks[2], (b, h, s, n)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (b, h, s, n)) * 0.5)
    u = jax.random.normal(ks[4], (h, n)) * 0.5
    out = rwkv6_wkv(r, k, v, logw, u, chunk=chunk, interpret=True)
    np.testing.assert_allclose(out, wkv_ref(r, k, v, logw, u), atol=2e-4)


def test_rwkv6_wkv_chunk_invariance():
    ks = jax.random.split(jax.random.key(3), 5)
    shp = (1, 2, 64, 16)
    r, k, v = (jax.random.normal(ks[i], shp) * 0.5 for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], shp) * 0.5)
    u = jax.random.normal(ks[4], (2, 16)) * 0.5
    outs = [rwkv6_wkv(r, k, v, logw, u, chunk=c, interpret=True)
            for c in (8, 16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=2e-4)


def test_rwkv6_matches_model_chunked():
    """Kernel semantics == the model's XLA chunked path."""
    from repro.models.rwkv import wkv_chunked

    ks = jax.random.split(jax.random.key(5), 5)
    b, h, s, n = 2, 2, 64, 16
    r, k, v = (jax.random.normal(ks[i], (b, s, h, n)) * 0.5 for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, n)) * 0.5)
    u = jax.random.normal(ks[4], (h, n)) * 0.5
    state = jnp.zeros((b, h, n, n))
    o_model, _ = wkv_chunked(r, k, v, logw, u, state, chunk=16)
    o_kernel = rwkv6_wkv(r.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), logw.transpose(0, 2, 1, 3),
                         u, chunk=16, interpret=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(o_kernel, o_model, atol=2e-4)


# ---------------------------------------------------------------------------
# MoE grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,c,d,f", [
    (4, 32, 64, 48), (2, 64, 128, 64), (8, 16, 32, 32), (1, 128, 256, 128),
])
def test_moe_gmm_shapes(e, c, d, f):
    ks = jax.random.split(jax.random.key(e * c), 2)
    x = jax.random.normal(ks[0], (e, c, d))
    w = jax.random.normal(ks[1], (e, d, f)) / np.sqrt(d)
    out = moe_gmm(x, w, block_c=16, block_f=16, block_k=32, interpret=True)
    np.testing.assert_allclose(out, moe_gmm_ref(x, w), atol=1e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-4),
                                        (jnp.bfloat16, 5e-2)])
def test_moe_gmm_dtypes(dtype, atol):
    ks = jax.random.split(jax.random.key(11), 2)
    x = jax.random.normal(ks[0], (2, 32, 64)).astype(dtype)
    w = (jax.random.normal(ks[1], (2, 64, 32)) / 8).astype(dtype)
    out = moe_gmm(x, w, block_c=16, block_f=16, block_k=32, interpret=True)
    assert out.dtype == dtype
    np.testing.assert_allclose(out.astype(np.float32),
                               moe_gmm_ref(x, w).astype(np.float32),
                               atol=atol)
