"""Fleet simulator tests: buddy-allocator invariants (hypothesis),
scheduler behaviour, and paper-shape reproductions (SG>95%, U-shaped SG)."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # property tests skip, the rest still run
    from tests._hypothesis_fallback import given, settings, st

from repro.core.goodput import compute_goodput, segment_goodput
from repro.fleet.cluster import Cluster, _BuddyPod
from repro.fleet.job import JobSpec
from repro.fleet.sim import FleetSim, SimConfig
from repro.fleet.workload import generate_jobs


# ---------------------------------------------------------------------------
# buddy allocator
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from([1, 2, 4, 8, 16, 32, 64]), min_size=1,
                max_size=40))
def test_buddy_alloc_release_conserves(sizes):
    pod = _BuddyPod(0, 256)
    offs = []
    for i, s in enumerate(sizes):
        off = pod.alloc(s)
        if off is not None:
            offs.append((off, s))
    for off, s in offs:
        pod.release(off)
    assert pod.free_chips() == 256
    assert pod.largest_slice() == 256  # fully coalesced


def test_buddy_no_overlap():
    pod = _BuddyPod(0, 64)
    seen = set()
    for s in [16, 8, 8, 4, 16, 4, 8]:
        off = pod.alloc(s)
        assert off is not None
        span = set(range(off, off + s))
        assert not span & seen
        seen |= span


def test_cluster_fragmentation_rejects_topology():
    """Paper Myth 1: free chips != schedulable slice."""
    c = Cluster(n_pods=1, pod_size=16)
    a = c.alloc("a", 4)
    b = c.alloc("b", 4)
    d = c.alloc("d", 4)
    assert c.free_chips() == 4
    c.release("b")
    assert c.free_chips() == 8      # 8 free chips...
    assert not c.can_fit(8)         # ...but no contiguous 8-slice
    assert c.can_fit(4)


def test_multipod_alloc():
    c = Cluster(n_pods=4, pod_size=64)
    assert c.alloc("xl", 128) is not None      # 2 whole pods
    assert c.alloc("xl2", 192) is None         # needs 3 pods, only 2 left
    c.release("xl")
    assert c.alloc("xl3", 128) is not None


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------

def _run(seed=0, target_load=0.6, **kw):
    cfg = SimConfig(n_pods=8, pod_size=256, horizon=3 * 24 * 3600,
                    seed=seed, **kw)
    sim = FleetSim(cfg)
    for j in generate_jobs(150, cfg.horizon, seed=seed, pg_table={},
                           capacity_chips=cfg.n_pods * cfg.pod_size,
                           target_load=target_load):
        sim.submit(j)
    return sim.run()


def test_sim_chip_time_conservation():
    sim = _run()
    for ivl in sim.intervals:
        assert ivl.t1 >= ivl.t0
        assert ivl.chips > 0
    # queued/partial are waiting states, not physical chip occupancy
    total_alloc = sum(i.chip_time for i in sim.intervals
                      if i.phase.value not in ("queued", "partial"))
    assert total_alloc <= sim.capacity_chip_time * 1.001


def test_sim_work_credited_only_once():
    sim = _run()
    for j, job in sim.jobs.items():
        assert job.checkpointed <= job.spec.work + 1e-6


def test_sg_by_size_u_shape():
    """Paper Fig 16: XL jobs see the best scheduling goodput (the
    preemption policy protects them); per-class SG counts gang assembly
    and restart gaps (PARTIAL), not initial queueing (see fig16 bench)."""
    sim = _run(seed=3)
    from collections import defaultdict

    partial = defaultdict(float)
    alloc = defaultdict(float)
    for ivl in sim.intervals:
        sc = ivl.segment["size_class"]
        if ivl.phase.value == "partial":
            partial[sc] += ivl.chip_time
        elif ivl.phase.value != "queued":
            alloc[sc] += ivl.chip_time
    sg = {s: alloc[s] / (alloc[s] + partial[s])
          for s in alloc if alloc[s] + partial[s] > 0}
    if "xl" in sg and "medium" in sg:
        assert sg["xl"] >= sg["medium"] - 0.05


def test_preemption_protects_xl():
    sim = _run(seed=5)
    by_class = {}
    for j, job in sim.jobs.items():
        sc = job.spec.size_class
        by_class.setdefault(sc, []).append(job.preemptions)
    if "xl" in by_class:
        assert sum(by_class["xl"]) == 0   # policy: never evict XL


def test_ledger_stream_matches_batch_computation():
    """The sim's streaming ledger report equals the legacy whole-list
    compute_goodput over the identical interval stream."""
    sim = _run(seed=2)
    batch = compute_goodput(sim.intervals, sim.capacity_chip_time,
                            sim.pg_by_job())
    stream = sim.report()
    assert stream.sg == pytest.approx(batch.sg)
    assert stream.rg == pytest.approx(batch.rg)
    assert stream.pg == pytest.approx(batch.pg)
    assert stream.mpg == pytest.approx(batch.mpg)


# ---------------------------------------------------------------------------
# pluggable policies (paper §5.3 / Fig. 16 ablations as a sweep)
# ---------------------------------------------------------------------------

POLICY_COMBOS = [
    ("best_fit", "protect_xl", "drain_for_xl"),    # the paper's policy
    ("first_fit", "priority_only", "migrate_small"),
    ("spread", "none", "none"),
    ("best_fit", "priority_only", "none"),
]


@pytest.mark.parametrize("placement,preemption,defrag", POLICY_COMBOS)
def test_policy_combos_preserve_invariants(placement, preemption, defrag):
    """Every injected policy combination must preserve the physical
    invariants: chip-time conservation, work credited at most once, and
    per-class SG in the paper's >95% regime at moderate load."""
    sim = _run(seed=11, placement=placement, preemption=preemption,
               defrag=defrag, target_load=0.5)
    total_alloc = sum(i.chip_time for i in sim.intervals
                      if i.phase.value not in ("queued", "partial"))
    assert total_alloc <= sim.capacity_chip_time * 1.001
    for job in sim.jobs.values():
        assert job.checkpointed <= job.spec.work + 1e-6
    by = sim.ledger.segment_phase_chip_time("size_class")
    partial = {s: p.get("partial", 0.0) for s, p in by.items()}
    alloc = {s: sum(ct for ph, ct in p.items()
                    if ph not in ("partial", "queued"))
             for s, p in by.items()}
    sg = {s: alloc[s] / (alloc[s] + partial[s])
          for s in alloc if alloc[s] + partial[s] > 0}
    overall = (sum(alloc.values())
               / (sum(alloc.values()) + sum(partial.values())))
    # naive policies legitimately lose SG (that is the ablation's point),
    # but accounting must stay physical
    assert 0.0 < overall <= 1.0
    if preemption == "protect_xl" and "xl" in sg and "medium" in sg:
        # U-shape: protected XL never does worse than the eviction class
        assert sg["xl"] >= sg["medium"] - 0.05


def test_paper_policy_sg_above_95():
    """Fig. 16's headline: the paper's policy (best_fit + protect_xl +
    drain_for_xl) holds overall SG > 95% at moderate fleet load (the
    fig16 benchmark's quick setting; heavier churn erodes it, seed code
    included)."""
    cfg = SimConfig(n_pods=16, pod_size=256, horizon=7 * 24 * 3600, seed=16)
    sim = FleetSim(cfg)
    for j in generate_jobs(200, cfg.horizon, seed=16,
                           capacity_chips=cfg.n_pods * cfg.pod_size,
                           target_load=0.5):
        sim.submit(j)
    sim.run()
    by = sim.ledger.segment_phase_chip_time("size_class")
    partial = sum(p.get("partial", 0.0) for p in by.values())
    alloc = sum(ct for p in by.values() for ph, ct in p.items()
                if ph not in ("partial", "queued"))
    assert alloc / (alloc + partial) > 0.95


def test_no_preemption_policy_never_evicts():
    sim = _run(seed=5, preemption="none")
    assert sum(j.preemptions for j in sim.jobs.values()) == 0


def test_priority_only_policy_can_evict_xl():
    """The ablation behaves differently from the paper's policy: without
    XL protection some run (across seeds) evicts an XL job."""
    evicted_xl = 0
    for seed in range(3, 8):
        sim = _run(seed=seed, preemption="priority_only")
        evicted_xl += sum(j.preemptions for j in sim.jobs.values()
                          if j.spec.size_class == "xl")
    protected = 0
    for seed in range(3, 8):
        sim = _run(seed=seed, preemption="protect_xl")
        protected += sum(j.preemptions for j in sim.jobs.values()
                         if j.spec.size_class == "xl")
    assert protected == 0
    assert evicted_xl >= protected


def test_unknown_policy_name_rejected():
    with pytest.raises(ValueError, match="placement"):
        FleetSim(SimConfig(placement="bogus"))
    with pytest.raises(ValueError, match="preemption"):
        FleetSim(SimConfig(preemption="bogus"))
    with pytest.raises(ValueError, match="defrag"):
        FleetSim(SimConfig(defrag="bogus"))


def test_retain_intervals_off_blocks_list_access():
    sim = _run(seed=0, retain_intervals=False)
    with pytest.raises(AttributeError):
        _ = sim.intervals
    assert sim.ledger.n_events > 0
    assert 0.0 < sim.report().sg <= 1.0


def test_async_checkpoint_improves_rg():
    """Paper §5.2: async checkpointing raises fleet RG."""
    def rg(async_ckpt):
        cfg = SimConfig(n_pods=4, pod_size=256, horizon=3 * 24 * 3600, seed=7)
        sim = FleetSim(cfg)
        for j in generate_jobs(150, cfg.horizon, seed=7,
                               async_checkpoint=async_ckpt, pg_table={}):
            sim.submit(j)
        sim.run()
        return compute_goodput(sim.intervals, sim.capacity_chip_time).rg

    assert rg(True) > rg(False)
