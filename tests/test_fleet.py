"""Fleet simulator tests: buddy-allocator invariants (hypothesis),
scheduler behaviour, and paper-shape reproductions (SG>95%, U-shaped SG)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.goodput import compute_goodput, segment_goodput
from repro.fleet.cluster import Cluster, _BuddyPod
from repro.fleet.job import JobSpec
from repro.fleet.sim import FleetSim, SimConfig
from repro.fleet.workload import generate_jobs


# ---------------------------------------------------------------------------
# buddy allocator
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from([1, 2, 4, 8, 16, 32, 64]), min_size=1,
                max_size=40))
def test_buddy_alloc_release_conserves(sizes):
    pod = _BuddyPod(0, 256)
    offs = []
    for i, s in enumerate(sizes):
        off = pod.alloc(s)
        if off is not None:
            offs.append((off, s))
    for off, s in offs:
        pod.release(off)
    assert pod.free_chips() == 256
    assert pod.largest_slice() == 256  # fully coalesced


def test_buddy_no_overlap():
    pod = _BuddyPod(0, 64)
    seen = set()
    for s in [16, 8, 8, 4, 16, 4, 8]:
        off = pod.alloc(s)
        assert off is not None
        span = set(range(off, off + s))
        assert not span & seen
        seen |= span


def test_cluster_fragmentation_rejects_topology():
    """Paper Myth 1: free chips != schedulable slice."""
    c = Cluster(n_pods=1, pod_size=16)
    a = c.alloc("a", 4)
    b = c.alloc("b", 4)
    d = c.alloc("d", 4)
    assert c.free_chips() == 4
    c.release("b")
    assert c.free_chips() == 8      # 8 free chips...
    assert not c.can_fit(8)         # ...but no contiguous 8-slice
    assert c.can_fit(4)


def test_multipod_alloc():
    c = Cluster(n_pods=4, pod_size=64)
    assert c.alloc("xl", 128) is not None      # 2 whole pods
    assert c.alloc("xl2", 192) is None         # needs 3 pods, only 2 left
    c.release("xl")
    assert c.alloc("xl3", 128) is not None


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------

def _run(seed=0, **kw):
    cfg = SimConfig(n_pods=8, pod_size=256, horizon=3 * 24 * 3600,
                    seed=seed, **kw)
    sim = FleetSim(cfg)
    for j in generate_jobs(150, cfg.horizon, seed=seed, pg_table={},
                           capacity_chips=cfg.n_pods * cfg.pod_size,
                           target_load=0.6):
        sim.submit(j)
    return sim.run()


def test_sim_chip_time_conservation():
    sim = _run()
    for ivl in sim.intervals:
        assert ivl.t1 >= ivl.t0
        assert ivl.chips > 0
    # queued/partial are waiting states, not physical chip occupancy
    total_alloc = sum(i.chip_time for i in sim.intervals
                      if i.phase.value not in ("queued", "partial"))
    assert total_alloc <= sim.capacity_chip_time * 1.001


def test_sim_work_credited_only_once():
    sim = _run()
    for j, job in sim.jobs.items():
        assert job.checkpointed <= job.spec.work + 1e-6


def test_sg_by_size_u_shape():
    """Paper Fig 16: XL jobs see the best scheduling goodput (the
    preemption policy protects them); per-class SG counts gang assembly
    and restart gaps (PARTIAL), not initial queueing (see fig16 bench)."""
    sim = _run(seed=3)
    from collections import defaultdict

    partial = defaultdict(float)
    alloc = defaultdict(float)
    for ivl in sim.intervals:
        sc = ivl.segment["size_class"]
        if ivl.phase.value == "partial":
            partial[sc] += ivl.chip_time
        elif ivl.phase.value != "queued":
            alloc[sc] += ivl.chip_time
    sg = {s: alloc[s] / (alloc[s] + partial[s])
          for s in alloc if alloc[s] + partial[s] > 0}
    if "xl" in sg and "medium" in sg:
        assert sg["xl"] >= sg["medium"] - 0.05


def test_preemption_protects_xl():
    sim = _run(seed=5)
    by_class = {}
    for j, job in sim.jobs.items():
        sc = job.spec.size_class
        by_class.setdefault(sc, []).append(job.preemptions)
    if "xl" in by_class:
        assert sum(by_class["xl"]) == 0   # policy: never evict XL


def test_async_checkpoint_improves_rg():
    """Paper §5.2: async checkpointing raises fleet RG."""
    def rg(async_ckpt):
        cfg = SimConfig(n_pods=4, pod_size=256, horizon=3 * 24 * 3600, seed=7)
        sim = FleetSim(cfg)
        for j in generate_jobs(150, cfg.horizon, seed=7,
                               async_checkpoint=async_ckpt, pg_table={}):
            sim.submit(j)
        sim.run()
        return compute_goodput(sim.intervals, sim.capacity_chip_time).rg

    assert rg(True) > rg(False)
