"""Decode path == teacher-forced forward (cache correctness) per family."""
import jax
import jax.numpy as jnp
import pytest

from repro.models import model
from repro.models.config import ModelConfig


def _mk(fam, **kw):
    return ModelConfig(
        name=f"t-{fam}", family=fam, num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=kw.pop("num_kv_heads", 2), d_ff=128,
        vocab_size=97, attn_chunk=8, compute_dtype=jnp.float32, **kw)


CASES = {
    "dense": _mk("dense"),
    "dense-swa": _mk("dense", attention_window=8),
    "dense-bias": _mk("dense", qkv_bias=True),
    "moe": _mk("moe", num_experts=4, experts_per_token=2,
               capacity_factor=64.0),   # high capacity: no token drops
    "moe-shared": _mk("moe", num_experts=4, experts_per_token=2,
                      num_shared_experts=1, first_k_dense=1,
                      d_ff_dense=192, capacity_factor=64.0),
    "ssm": _mk("ssm", rwkv_head_dim=16),
    "hybrid": _mk("hybrid", attn_every=3, attention_window=16, lru_width=64,
                  num_kv_heads=1),
    "vlm": _mk("vlm", num_patches=8),
    "encdec": _mk("encdec", encoder_layers=2, encoder_positions=24,
                  norm_type="layernorm", mlp_gated=False,
                  mlp_activation="gelu", tie_embeddings=True, qkv_bias=True),
}


@pytest.mark.parametrize("name", sorted(CASES))
def test_decode_matches_forward(name):
    cfg = CASES[name]
    params = model.init_params(cfg, jax.random.key(0))
    S = 24
    toks = jax.random.randint(jax.random.key(1), (2, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["patches"] = 0.1 * jax.random.normal(
            jax.random.key(2), (2, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.key(2), (2, cfg.encoder_positions, cfg.d_model),
            jnp.float32)

    b1 = dict(batch)
    b1["tokens"] = toks[:, : S - 1]
    _, cache = jax.jit(model.prefill_fn(cfg))(params, b1)
    logits_dec, cache2 = jax.jit(model.decode_fn(cfg))(
        params, toks[:, S - 1], cache)
    logits_full, _ = jax.jit(model.prefill_fn(cfg))(params, batch)
    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    assert err < 2e-3, f"{name}: decode != forward (max err {err:.2e})"


def test_multi_step_decode_greedy():
    """8 decode steps == 8 incremental prefills (greedy continuation)."""
    cfg = CASES["dense-swa"]
    params = model.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    from repro.models import transformer

    logits, cache = jax.jit(
        lambda p, b: transformer.prefill(p, b, cfg, max_len=24)
    )(params, {"tokens": toks})
    dfn = jax.jit(model.decode_fn(cfg))
    pfn = jax.jit(model.prefill_fn(cfg))
    cur = toks
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(8):
        logits_dec, cache = dfn(params, tok, cache)
        cur = jnp.concatenate([cur, tok[:, None]], axis=1)
        logits_full, _ = pfn(params, {"tokens": cur})
        err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
        assert err < 2e-3, f"step err {err:.2e}"
        tok = jnp.argmax(logits_full, -1).astype(jnp.int32)
