"""Resiliency semantics: elastic resume, multi-slice gang jobs, reshard
accounting, and rigid gang replacement — pinned identically on both
engines.

The elastic-resume pinning tests were written against the pre-gang
engines (the half-slice restart path in ``fleet/sim.py``) and must keep
passing through the multi-slice refactor: an elastic single-slice job
preempted out of a full cluster restarts on half its slice instead of
waiting for the full shape.
"""
import dataclasses
import random

import pytest

from repro.core.goodput import LOSS_BUCKETS, Layer, Phase
from repro.fleet.job import JobSpec
from repro.fleet.scenarios import (GOLDEN_KNOBS, GOLDEN_SIZE_MIX, SCENARIOS,
                                   FailureBurst, Scenario, build_sim)
from repro.fleet.sim import REPAIR_LOGNORMAL, FleetSim, SimConfig
from repro.parallel.reshard import reshard_seconds

ENGINES = ("reference", "vectorized")

NO_FAILURES = 1e15          # chip_mtbf high enough that no segment fails


def _first_repair_s(seed: int, scale: float, gen: str = "tpu-v5e") -> float:
    """The first repair window a seed-``seed`` sim draws: ``scale`` times
    the generation's lognormal multiplier, first draw on the dedicated
    ``{seed}:repair`` stream (the sim's exact sampling recipe)."""
    rng = random.Random(f"{seed}:repair")
    return scale * rng.lognormvariate(*REPAIR_LOGNORMAL[gen])


def _elastic_preempt_sim(engine, **kw):
    """One pod of 8; an elastic 8-chip job is preempted by a priority-5
    arrival and can only get half its slice back."""
    cfg = SimConfig(n_pods=1, pod_size=8, horizon=40_000.0, seed=0,
                    chip_mtbf=NO_FAILURES, engine=engine, **kw)
    sim = FleetSim(cfg)
    sim.submit(JobSpec("low", chips=8, work=8 * 30_000.0, priority=1,
                       elastic=True, arrival=0.0))
    sim.submit(JobSpec("high", chips=4, work=4 * 1e9, priority=5,
                       arrival=1_000.0))
    # a later arrival triggers the scheduling pass that restarts "low"
    sim.submit(JobSpec("late", chips=4, work=4 * 1e9, priority=1,
                       arrival=2_000.0))
    sim.run()
    return sim


@pytest.mark.parametrize("engine", ENGINES)
def test_elastic_resume_restarts_on_half_slice(engine):
    sim = _elastic_preempt_sim(engine)
    low = sim.jobs["low"]
    assert low.preemptions == 1
    # the pinned behaviour: preempted elastic job degraded to half width
    assert low.spec.chips == 4
    # its requeued wait is PARTIAL (restart gap), not initial QUEUED
    partial = [i for i in sim.intervals
               if i.job_id == "low" and i.phase is Phase.PARTIAL]
    assert partial, "requeued elastic job must book a PARTIAL wait"
    # every post-restart interval runs on the degraded slice
    t_restart = max(i.t0 for i in partial)
    after = [i for i in sim.intervals
             if i.job_id == "low" and i.phase is Phase.STEP
             and i.t0 >= t_restart]
    assert after and all(i.chips == 4 for i in after)


@pytest.mark.parametrize("engine", ENGINES)
def test_elastic_resume_conserves_work(engine):
    sim = _elastic_preempt_sim(engine)
    for job in sim.jobs.values():
        assert job.checkpointed <= job.spec.work + 1e-6


def test_elastic_resume_identical_across_engines():
    ref = _elastic_preempt_sim("reference")
    vec = _elastic_preempt_sim("vectorized")
    assert ref.ledger.totals() == vec.ledger.totals()
    for j in ref.jobs:
        assert ref.jobs[j].spec == vec.jobs[j].spec
        assert ref.jobs[j].preemptions == vec.jobs[j].preemptions


def test_inelastic_job_waits_instead_of_degrading():
    """Same setup, elastic off: the preempted job never halves."""
    cfg = SimConfig(n_pods=1, pod_size=8, horizon=40_000.0, seed=0,
                    chip_mtbf=NO_FAILURES, engine="reference")
    sim = FleetSim(cfg)
    sim.submit(JobSpec("low", chips=8, work=8 * 30_000.0, priority=1,
                       elastic=False, arrival=0.0))
    sim.submit(JobSpec("high", chips=4, work=4 * 1e9, priority=5,
                       arrival=1_000.0))
    sim.submit(JobSpec("late", chips=4, work=4 * 1e9, priority=1,
                       arrival=2_000.0))
    sim.run()
    assert sim.jobs["low"].spec.chips == 8


# ---------------------------------------------------------------------------
# multi-slice gangs: slice-granularity failures
# ---------------------------------------------------------------------------

def _one_burst(at_frac: float = 0.5) -> Scenario:
    """A correlated shock that kills (one slice of) every running job."""
    return Scenario("kill_all",
                    bursts=(FailureBurst(at_frac=at_frac, kill_frac=1.0),))


@pytest.mark.parametrize("engine", ENGINES)
def test_elastic_gang_degrades_in_place(engine):
    """A slice failure on an elastic 2-slice gang sheds the dead slice and
    restarts on the survivor immediately — one RESHARD transfer, no
    requeue."""
    cfg = SimConfig(n_pods=1, pod_size=8, horizon=40_000.0, seed=0,
                    chip_mtbf=NO_FAILURES, engine=engine,
                    scenario=_one_burst())
    sim = FleetSim(cfg)
    sim.submit(JobSpec("gang", chips=8, n_slices=2, work=8 * 1e9,
                       elastic=True, arrival=0.0))
    sim.run()
    gang = sim.jobs["gang"]
    assert gang.failures == 1
    assert gang.preemptions == 0           # degraded in place, not requeued
    assert gang.spec.chips == 4 and gang.spec.n_slices == 1
    reshard = [i for i in sim.intervals if i.phase is Phase.RESHARD]
    assert len(reshard) == 1
    expected = reshard_seconds("smollm-135m", 8, 4)
    assert expected > 0
    assert reshard[0].t1 - reshard[0].t0 == pytest.approx(expected)
    assert Layer(reshard[0].segment["layer"]) is Layer.SCHEDULING
    assert LOSS_BUCKETS[(Phase.RESHARD, Layer.SCHEDULING)] == \
        "reshard_transfer"


@pytest.mark.parametrize("engine", ENGINES)
def test_elastic_gang_regrows_to_target(engine):
    """Degraded once, killed again: the requeued elastic job regrows to
    its submitted gang shape, paying the reshard back up."""
    cfg = SimConfig(n_pods=1, pod_size=8, horizon=60_000.0, seed=0,
                    chip_mtbf=NO_FAILURES, engine=engine,
                    scenario=Scenario("two_kills", bursts=(
                        FailureBurst(at_frac=0.3, kill_frac=1.0),
                        FailureBurst(at_frac=0.6, kill_frac=1.0))))
    sim = FleetSim(cfg)
    sim.submit(JobSpec("gang", chips=8, n_slices=2, work=8 * 1e9,
                       elastic=True, arrival=0.0))
    sim.run()
    gang = sim.jobs["gang"]
    assert gang.failures == 2
    # burst 1 degraded 8->4; burst 2 killed the lone slice and the regrow
    # path restored the submitted 2x4 shape on the empty pod
    assert gang.spec.chips == 8 and gang.spec.n_slices == 2
    reshard = sorted((i.t0, i.t1 - i.t0) for i in sim.intervals
                     if i.phase is Phase.RESHARD)
    assert len(reshard) == 2
    assert reshard[0][1] == pytest.approx(reshard_seconds("smollm-135m", 8, 4))
    assert reshard[1][1] == pytest.approx(reshard_seconds("smollm-135m", 4, 8))


@pytest.mark.parametrize("engine", ENGINES)
def test_rigid_gang_books_gang_stall(engine):
    """A rigid gang whose replacement slice is crowded out holds its
    survivors: the hold books as hardware-layer IDLE (gang_stall) on the
    surviving width, and the job neither degrades nor dies."""
    cfg = SimConfig(n_pods=3, pod_size=64, horizon=40_000.0, seed=0,
                    chip_mtbf=NO_FAILURES, engine=engine,
                    scenario=_one_burst())
    sim = FleetSim(cfg)
    # rigid 2x64 gang: too wide for drain-migration, protected by priority
    sim.submit(JobSpec("gang", chips=128, n_slices=2, work=128 * 1e9,
                       elastic=False, priority=5, arrival=0.0))
    # queued multi-pod job that grabs the freed pods the instant the
    # burst kills a gang slice, starving the replacement
    sim.submit(JobSpec("xl", chips=128, work=128 * 1e9, priority=1,
                       arrival=1_000.0))
    sim.run()
    gang = sim.jobs["gang"]
    assert gang.failures == 1
    assert gang.spec.chips == 128 and gang.spec.n_slices == 2  # never shrank
    stall = [i for i in sim.intervals
             if i.job_id == "gang" and i.phase is Phase.IDLE]
    assert len(stall) == 1
    assert stall[0].chips == 64            # the surviving slice, not 128
    assert stall[0].t0 == pytest.approx(20_000.0)  # the burst instant
    assert stall[0].t1 == pytest.approx(40_000.0)  # held to the horizon
    assert Layer(stall[0].segment["layer"]) is Layer.HARDWARE
    assert LOSS_BUCKETS[(Phase.IDLE, Layer.HARDWARE)] == "gang_stall"
    # the xl job did take over the two freed pods
    xl_steps = [i for i in sim.intervals
                if i.job_id == "xl" and i.phase is Phase.STEP]
    assert xl_steps and all(i.t0 >= 20_000.0 for i in xl_steps)


def _storm_totals(engine, elastic, slice_repair_s=0.0):
    sim = build_sim(SCENARIOS["failure_storm"], size_mix=GOLDEN_SIZE_MIX,
                    engine=engine, slice_repair_s=slice_repair_s,
                    job_mutator=lambda j: dataclasses.replace(
                        j, elastic=elastic),
                    **GOLDEN_KNOBS)
    sim.run()
    return sim.ledger.totals()


@pytest.mark.parametrize("elastic", (False, True))
def test_failure_storm_identical_across_engines(elastic):
    """Slice failures + (rigid|elastic) gang handling are bit-identical
    across engines on the storm preset."""
    assert _storm_totals("reference", elastic) == \
        _storm_totals("vectorized", elastic)


# ---------------------------------------------------------------------------
# repair windows: failed hardware leaves service for slice_repair_s
# ---------------------------------------------------------------------------

def test_slice_repair_s_validated():
    with pytest.raises(ValueError, match="slice_repair_s"):
        SimConfig(slice_repair_s=-1.0)


@pytest.mark.parametrize("engine", ENGINES)
def test_repair_window_stalls_rigid_gang_exactly(engine):
    """On a full pod there is no spare capacity: a rigid gang's
    replacement slice only exists once the dead slice's chips come back
    from repair — the gang_stall duration IS the (sampled) repair
    window."""
    cfg = SimConfig(n_pods=1, pod_size=8, horizon=40_000.0, seed=0,
                    chip_mtbf=NO_FAILURES, engine=engine,
                    slice_repair_s=3_600.0, scenario=_one_burst())
    sim = FleetSim(cfg)
    sim.submit(JobSpec("gang", chips=8, n_slices=2, work=8 * 1e9,
                       elastic=False, arrival=0.0))
    sim.run()
    gang = sim.jobs["gang"]
    assert gang.failures == 1
    assert gang.spec.chips == 8 and gang.spec.n_slices == 2
    repair_done = 20_000.0 + _first_repair_s(seed=0, scale=3_600.0)
    stall = [i for i in sim.intervals
             if i.job_id == "gang" and i.phase is Phase.IDLE]
    assert len(stall) == 1
    assert stall[0].t0 == pytest.approx(20_000.0)          # the burst
    assert stall[0].t1 == pytest.approx(repair_done)       # repair done
    assert LOSS_BUCKETS[(Phase.IDLE, Layer.HARDWARE)] == "gang_stall"
    # full-width STEPs resume after the refill
    after = [i for i in sim.intervals
             if i.job_id == "gang" and i.phase is Phase.STEP
             and i.t0 >= repair_done - 1e-6]
    assert after and all(i.chips == 8 for i in after)


@pytest.mark.parametrize("engine", ENGINES)
def test_repair_window_elastic_regrows_when_repair_completes(engine):
    """The elastic counterpart: degrade on the survivors through the
    repair window, then opportunistically regrow to the submitted shape
    the moment the chips return — paying the reshard both ways."""
    cfg = SimConfig(n_pods=1, pod_size=8, horizon=40_000.0, seed=0,
                    chip_mtbf=NO_FAILURES, engine=engine,
                    slice_repair_s=3_600.0, scenario=_one_burst())
    sim = FleetSim(cfg)
    sim.submit(JobSpec("gang", chips=8, n_slices=2, work=8 * 1e9,
                       elastic=True, arrival=0.0))
    sim.run()
    gang = sim.jobs["gang"]
    assert gang.failures == 1
    assert gang.preemptions == 0
    assert gang.spec.chips == 8 and gang.spec.n_slices == 2
    reshard = sorted((i.t0, i.t1 - i.t0) for i in sim.intervals
                     if i.phase is Phase.RESHARD)
    assert len(reshard) == 2                   # 8->4 down, 4->8 back up
    assert reshard[0][1] == pytest.approx(reshard_seconds("smollm-135m", 8, 4))
    assert reshard[1][1] == pytest.approx(reshard_seconds("smollm-135m", 4, 8))
    # degraded STEPs span the sampled repair window; full width resumes
    # after the chips return
    repair_done = 20_000.0 + _first_repair_s(seed=0, scale=3_600.0)
    degraded = [i for i in sim.intervals
                if i.job_id == "gang" and i.phase is Phase.STEP
                and i.chips == 4]
    assert degraded and all(20_000.0 <= i.t0 <= repair_done + 1e-6
                            for i in degraded)


@pytest.mark.parametrize("engine", ENGINES)
def test_repair_window_elastic_beats_rigid(engine):
    """The resiliency headline at test scale: with a repair window, the
    elastic gang out-produces the rigid one on the same hardware."""
    def mpg(elastic):
        cfg = SimConfig(n_pods=1, pod_size=8, horizon=40_000.0, seed=0,
                        chip_mtbf=NO_FAILURES, engine=engine,
                        retain_intervals=False,
                        slice_repair_s=3_600.0, scenario=_one_burst())
        sim = FleetSim(cfg)
        sim.submit(JobSpec("gang", chips=8, n_slices=2, work=8 * 1e9,
                           elastic=elastic, arrival=0.0))
        sim.run()
        return sim.report().mpg
    assert mpg(True) > mpg(False)


@pytest.mark.parametrize("preset", ("failure_storm", "maintenance",
                                    "peak_week"))
@pytest.mark.parametrize("elastic", (False, True))
def test_repair_window_identical_across_engines(preset, elastic):
    """Repair sentinels, timed releases, maintenance subsumption, and
    opportunistic regrow are bit-identical across engines."""
    def totals(engine):
        sim = build_sim(SCENARIOS[preset], size_mix=GOLDEN_SIZE_MIX,
                        engine=engine, slice_repair_s=4 * 3600.0,
                        job_mutator=lambda j: dataclasses.replace(
                            j, elastic=elastic),
                        **GOLDEN_KNOBS)
        sim.run()
        return sim.ledger.totals()
    assert totals("reference") == totals("vectorized")
