"""Property-based tests for the trace/ledger pipeline (hypothesis; the
example-based mirrors below the properties run even without it).

Properties:
  * chip-time conservation — for arbitrary event streams, every window of
    the SG/RG/PG series satisfies ``goodput + RG-loss = allocated`` and
    the windows sum back to the aggregate totals;
  * ``replay(record(sim))`` is idempotent — the replayed ledger totals
    equal the recorded footer exactly, and a second record/replay of the
    serialized trace is byte-stable;
  * every scenario modifier keeps SG/RG/PG in [0, 1].
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # property tests skip, the rest still run
    from tests._hypothesis_fallback import given, settings, st

from repro.core.goodput import (ALLOCATED_PHASES, PRODUCTIVE_PHASES,
                                Interval, Phase)
from repro.core.ledger import GoodputLedger
from repro.fleet.scenarios import SCENARIOS, build_sim, golden_sim
from repro.fleet.trace import Trace, record, replay

RG_LOSS_PHASES = sorted(p.value for p in ALLOCATED_PHASES
                        if p not in PRODUCTIVE_PHASES)
WAIT_PHASES = sorted(p.value for p in Phase
                     if p not in ALLOCATED_PHASES)


# ---------------------------------------------------------------------------
# shared assertion helpers (used by both property and example tests)
# ---------------------------------------------------------------------------

def _stream(seed, n):
    rng = random.Random(seed)
    phases = list(Phase)
    out = []
    for _ in range(n):
        t0 = rng.uniform(0, 40_000.0)
        out.append(Interval(
            job_id=f"job{rng.randrange(6)}", phase=rng.choice(phases),
            t0=t0, t1=t0 + rng.uniform(0, 9_000.0),
            chips=rng.choice([1, 4, 64]),
            segment={"size_class": rng.choice(("small", "xl"))}))
    return out


def assert_window_conservation(ledger):
    """Per window: goodput + RG-loss chip-time = allocated chip-time, and
    the windowed series sums back to the ledger's aggregate totals."""
    total_alloc = total_prod = 0.0
    for acc in ledger._windows.values():
        prod = sum(acc.phase.get(p.value, 0.0) for p in PRODUCTIVE_PHASES)
        loss = sum(acc.phase.get(p, 0.0) for p in RG_LOSS_PHASES)
        assert prod + loss == pytest.approx(acc.allocated)
        assert acc.productive == pytest.approx(prod)
        total_alloc += acc.allocated
        total_prod += acc.productive
    rep = ledger.report(1.0)
    assert total_alloc == pytest.approx(rep.allocated_chip_time)
    assert total_prod == pytest.approx(rep.productive_chip_time)


def assert_replay_idempotent(sim):
    trace = record(sim)
    first = replay(trace)
    assert first.totals() == trace.totals          # exact, not approx
    # serialize -> parse -> replay is just as exact, and re-serialization
    # is byte-stable
    text = trace.dumps()
    parsed = Trace.loads(text)
    assert replay(parsed).totals() == trace.totals
    assert parsed.dumps() == text


# ---------------------------------------------------------------------------
# properties (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=300))
def test_window_series_conserves_chip_time(seed, n):
    led = GoodputLedger(window=3600.0, retain_intervals=False)
    pg_rng = random.Random(seed + 1)
    for iv in _stream(seed, n):
        led.record(iv, pg=pg_rng.uniform(0.1, 1.0))
    assert_window_conservation(led)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=50))
def test_replay_of_recorded_sim_is_idempotent(seed):
    sim = build_sim(SCENARIOS["failure_storm"], n_jobs=10, seed=seed,
                    n_pods=2, pod_size=32, horizon=6 * 3600.0,
                    retain_intervals=False)
    assert_replay_idempotent(sim)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(sorted(SCENARIOS)),
       st.integers(min_value=0, max_value=20))
def test_scenario_modifiers_keep_goodput_in_unit_range(preset, seed):
    sim = build_sim(SCENARIOS[preset], n_jobs=12, seed=seed,
                    n_pods=2, pod_size=32, horizon=8 * 3600.0,
                    retain_intervals=False)
    sim.run()
    rep = sim.report()
    assert 0.0 <= rep.sg <= 1.0
    assert 0.0 <= rep.rg <= 1.0
    assert 0.0 <= rep.pg <= 1.0
    assert 0.0 <= rep.mpg <= 1.0


# ---------------------------------------------------------------------------
# example-based mirrors (always run, hypothesis or not)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 3, 11])
def test_window_series_conserves_chip_time_examples(seed):
    led = GoodputLedger(window=3600.0, retain_intervals=False)
    for iv in _stream(seed, 200):
        led.record(iv, pg=0.5)
    assert_window_conservation(led)


@pytest.mark.parametrize("preset", ["steady", "maintenance", "peak_week"])
def test_replay_idempotent_examples(preset):
    assert_replay_idempotent(golden_sim(preset))


def test_replay_into_shared_ledger_merges_capacity():
    t1 = record(golden_sim("steady"))
    t2 = record(golden_sim("bursty"))
    merged = replay(t1)
    merged.add_capacity(t2.capacity_chip_time)
    replay(t2, ledger=merged)
    assert merged.n_events == len(t1.events) + len(t2.events)
    cap = t1.capacity_chip_time + t2.capacity_chip_time
    assert merged.capacity_chip_time == cap
    assert 0.0 <= merged.report().mpg <= 1.0
