"""Roofline math + analytic FLOPS model unit tests."""
import pytest

from repro.configs import get_config
from repro.core.flops import model_flops
from repro.core.hardware import TPU_V5E, ideal_step_time
from repro.core.roofline import RooflineCell, fit_poly_and_eval
from repro.models.config import SHAPES_BY_NAME


def test_roofline_terms_and_dominance():
    cell = RooflineCell(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops=256 * 197e12 * 1.0,          # exactly 1 s of compute
        hlo_bytes=256 * 819e9 * 0.5,           # 0.5 s of memory
        collective_bytes_per_chip=50e9 * 2.0,  # 2 s of collectives
        model_flops=256 * 197e12 * 0.7,
    )
    assert cell.t_compute == pytest.approx(1.0)
    assert cell.t_memory == pytest.approx(0.5)
    assert cell.t_collective == pytest.approx(2.0)
    assert cell.dominant == "collective"
    assert cell.t_lower_bound == pytest.approx(2.0)
    assert cell.t_no_overlap == pytest.approx(3.5)
    assert cell.useful_ratio == pytest.approx(0.7)
    assert cell.pg_optimistic == pytest.approx(0.7 / 2.0)


def test_model_flops_moe_uses_active_params():
    mix = get_config("mixtral-8x7b")
    shape = SHAPES_BY_NAME["train_4k"]
    mf = model_flops(mix, shape)
    assert mf == pytest.approx(6.0 * mix.num_active_params() * shape.tokens)
    assert mf < 6.0 * mix.num_params() * shape.tokens * 0.5


def test_model_flops_decode_counts_batch_tokens():
    cfg = get_config("granite-3-8b")
    d = SHAPES_BY_NAME["decode_32k"]
    assert model_flops(cfg, d) == pytest.approx(
        2.0 * cfg.num_active_params() * 128)


def test_ideal_step_time_is_paper_pg_numerator():
    assert ideal_step_time(197e12 * 256, 256) == pytest.approx(1.0)


def test_poly_fit_exact_for_quadratic():
    f = lambda x: 3.0 + 2.0 * x + 0.5 * x * x  # noqa: E731
    xs = [2, 4, 6]
    assert fit_poly_and_eval(xs, [f(x) for x in xs], 80) == pytest.approx(f(80))


def test_poly_fit_linear_with_two_points():
    f = lambda x: 7.0 + 3.0 * x  # noqa: E731
    assert fit_poly_and_eval([1, 2], [f(1), f(2)], 256) == pytest.approx(f(256))
