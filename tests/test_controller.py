"""Adaptive-controller tests: cross-engine bit-identity of controlled
runs, safety properties of the decision core (cooldown as a hypothesis
property, no switches without evidence), visible switch overhead in the
waterfall, and the scheduler-rescue policy swap."""
import dataclasses
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # property tests skip, the rest still run
    from tests._hypothesis_fallback import given, settings, st

from repro.core.attribution import AttributionWaterfall
from repro.fleet.controller import (AdaptiveController, ControllerConfig,
                                    Signals)
from repro.fleet.policies import NAIVE_COMBO, PAPER_COMBO
from repro.fleet.scenarios import (GOLDEN_KNOBS, GOLDEN_SIZE_MIX, SCENARIOS,
                                   build_sim)

ENGINES = ("reference", "vectorized")


def _controlled(preset: str, engine: str, **kw):
    ctrl = AdaptiveController()
    sim = build_sim(SCENARIOS[preset], size_mix=GOLDEN_SIZE_MIX,
                    engine=engine, controller=ctrl,
                    **{**GOLDEN_KNOBS, **kw})
    sim.run()
    return sim, ctrl


# ---------------------------------------------------------------------------
# cross-engine equivalence under live control
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", ("failure_storm", "peak_week",
                                    "maintenance"))
def test_controlled_run_identical_across_engines(preset):
    """A controlled run — including mid-run policy flips, evictions, and
    Daly retunes — streams bit-identical ledger totals and takes the
    identical switch sequence on both engines."""
    runs = {}
    for engine in ENGINES:
        sim, ctrl = _controlled(preset, engine)
        runs[engine] = (sim.ledger.totals(), ctrl.switches)
    assert runs["reference"][0] == runs["vectorized"][0]
    assert runs["reference"][1] == runs["vectorized"][1]


def test_controller_acts_on_failure_storm():
    sim, ctrl = _controlled("failure_storm", "vectorized")
    assert ctrl.switches, "storm preset must trigger at least one switch"
    assert ctrl.switches[0]["rule"] == "failure_storm"
    assert ctrl.mode in ("survival", "baseline")


# ---------------------------------------------------------------------------
# no evidence, no switches
# ---------------------------------------------------------------------------

def test_no_faults_never_switches():
    """On the steady preset with failures effectively disabled there is
    no storm, maintenance, queue, or gang evidence — the controller must
    hold the baseline for the whole run on both engines."""
    quiet = dataclasses.replace(SCENARIOS["steady"], mtbf_factor=1e9)
    for engine in ENGINES:
        ctrl = AdaptiveController()
        sim = build_sim(quiet, size_mix=GOLDEN_SIZE_MIX, engine=engine,
                        controller=ctrl, **GOLDEN_KNOBS)
        sim.run()
        assert ctrl.switches == []
        assert ctrl.mode == "baseline"


# ---------------------------------------------------------------------------
# switch overhead is visible in the waterfall
# ---------------------------------------------------------------------------

def test_switch_overhead_lands_in_policy_switch_bucket():
    sim, ctrl = _controlled("failure_storm", "vectorized")
    buckets = ctrl.waterfall.bucket_totals()
    cfg = ctrl.cfg
    expect = len(ctrl.switches) * cfg.switch_cost_s * cfg.switch_chips
    assert ctrl.switches
    assert buckets["policy_switch"] == pytest.approx(expect)
    ctrl.waterfall.assert_conserves(sim.ledger)


# ---------------------------------------------------------------------------
# scheduler rescue: naive live policies get swapped to the paper combo
# ---------------------------------------------------------------------------

def test_scheduler_rescue_swaps_to_paper_combo():
    saturated = dataclasses.replace(SCENARIOS["steady"], target_load=1.5)
    runs = {}
    for engine in ENGINES:
        ctrl = AdaptiveController()
        sim = build_sim(saturated, size_mix=GOLDEN_SIZE_MIX, engine=engine,
                        controller=ctrl, **{**GOLDEN_KNOBS, **NAIVE_COMBO})
        sim.run()
        rules = [s["rule"] for s in ctrl.switches]
        assert "scheduler_rescue" in rules
        assert sim.placement.name == PAPER_COMBO["placement"]
        assert sim.preemption.name == PAPER_COMBO["preemption"]
        assert sim.defrag.name == PAPER_COMBO["defrag"]
        runs[engine] = (sim.ledger.totals(), ctrl.switches)
    assert runs["reference"] == runs["vectorized"]


# ---------------------------------------------------------------------------
# decision-core safety properties (synthetic Signals, no sim)
# ---------------------------------------------------------------------------

def _signal(t, **kw):
    base = dict(t=t, failures_delta=0, expected_failures=0.05,
                cum_rate_x=0.0, rollback_frac=0.0, gang_waiting=0,
                maintenance=False, queue_frac=0.0, paper_policies=True,
                sg=0.9, mpg=0.5)
    base.update(kw)
    return Signals(**base)


@settings(max_examples=100, deadline=None)
@given(st.lists(
    st.tuples(st.floats(min_value=1.0, max_value=7200.0),
              st.integers(min_value=0, max_value=40),
              st.floats(min_value=0.0, max_value=0.6),
              st.booleans(),
              st.integers(min_value=0, max_value=5)),
    min_size=1, max_size=60))
def test_cooldown_never_allows_two_switches_within_window(steps):
    """However hostile the signal stream, accepted switches are at least
    ``cooldown_s`` apart — the anti-thrash guarantee is structural, not
    a property of friendly inputs."""
    ctrl = AdaptiveController()
    t = 0.0
    for dt, fails, rollback, maint, gangs in steps:
        t += dt
        ctrl._consider(_signal(t, failures_delta=fails,
                               rollback_frac=rollback, maintenance=maint,
                               gang_waiting=gangs,
                               cum_rate_x=fails * 3.0))
    times = [s["t"] for s in ctrl.switches]
    assert all(b - a >= ctrl.cfg.cooldown_s
               for a, b in zip(times, times[1:]))


def test_cooldown_holds_under_seeded_hostile_stream():
    """Deterministic mirror of the hypothesis property (runs even
    without hypothesis installed): 500 seeded hostile boundaries, every
    accepted pair of switches at least a cooldown apart."""
    import random
    rng = random.Random(20260809)
    ctrl = AdaptiveController()
    t = 0.0
    for _ in range(500):
        t += rng.uniform(60.0, 5400.0)
        ctrl._consider(_signal(
            t, failures_delta=rng.randrange(0, 30),
            rollback_frac=rng.uniform(0.0, 0.5),
            maintenance=rng.random() < 0.3,
            gang_waiting=rng.randrange(0, 4),
            cum_rate_x=rng.uniform(0.0, 8.0)))
    times = [s["t"] for s in ctrl.switches]
    assert times, "hostile stream must trigger switches"
    assert all(b - a >= ctrl.cfg.cooldown_s
               for a, b in zip(times, times[1:]))


def test_quiet_signals_propose_nothing():
    ctrl = AdaptiveController()
    for i in range(1, 50):
        assert ctrl._consider(_signal(3600.0 * i)) is None
    assert ctrl.switches == [] and ctrl.mode == "baseline"


def test_storm_then_calm_round_trip():
    """Entry on a mass-failure boundary, exit only after the configured
    number of calm boundaries — and re-entry still honors the cooldown."""
    cfg = ControllerConfig(cooldown_s=0.0)
    ctrl = AdaptiveController(cfg)
    a = ctrl._consider(_signal(3600.0, failures_delta=10, cum_rate_x=5.0))
    assert a is not None and ctrl.mode == "survival"
    # one calm boundary is not enough (calm_boundaries=2)
    assert ctrl._consider(_signal(7200.0)) is None
    exit_ = ctrl._consider(_signal(10800.0))
    assert exit_ is not None and exit_.rule == "calm_restore"
    assert ctrl.mode == "baseline"


def test_calm_exit_vetoed_while_cumulative_rate_high():
    """A degraded fleet (cum observed rate >> nominal) never looks calm,
    no matter how quiet one boundary is."""
    cfg = ControllerConfig(cooldown_s=0.0)
    ctrl = AdaptiveController(cfg)
    ctrl._consider(_signal(3600.0, failures_delta=10, cum_rate_x=5.0))
    assert ctrl.mode == "survival"
    for i in range(2, 12):
        ctrl._consider(_signal(3600.0 * i, cum_rate_x=4.0))
    assert ctrl.mode == "survival"


# ---------------------------------------------------------------------------
# config validation and binding
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="windows_per_decision"):
        ControllerConfig(windows_per_decision=0)
    with pytest.raises(ValueError, match="cooldown_s"):
        ControllerConfig(cooldown_s=-1.0)
    with pytest.raises(ValueError, match="hysteresis"):
        ControllerConfig(calm_rollback_frac=0.5, storm_rollback_frac=0.2)


def test_double_bind_rejected():
    ctrl = AdaptiveController()
    sim = build_sim(SCENARIOS["steady"], size_mix=GOLDEN_SIZE_MIX,
                    controller=ctrl, **GOLDEN_KNOBS)
    with pytest.raises(ValueError, match="already bound"):
        ctrl.bind(sim)


def test_initial_state():
    ctrl = AdaptiveController()
    assert ctrl.mode == "baseline"
    assert ctrl.switches == []
    assert ctrl._last_switch_t == -math.inf
