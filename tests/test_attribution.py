"""Attribution-waterfall tests: exact chip-time conservation (the PR's
acceptance bar), layer routing, and the Layer enum plumbing.

The conservation contract has two teeth:

  * the waterfall's float mirror must equal ``ledger.totals()`` with
    plain ``==`` — bit-for-bit, no approx — on every scenario preset,
    every golden trace, and arbitrary hypothesis-generated streams;
  * the per-(layer, phase) cells must partition allocated chip-time in
    exact rational arithmetic — a misrouted or dropped event cannot hide
    in float slack.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # property tests skip, the rest still run
    from tests._hypothesis_fallback import given, settings, st

from repro.core.attribution import AttributionWaterfall, waterfall_from_trace
from repro.core.goodput import (DEFAULT_LAYER, Interval, Layer, Phase,
                                layer_of, loss_bucket)
from repro.core.ledger import GoodputLedger
from repro.fleet.scenarios import SCENARIOS, golden_sim
from repro.fleet.trace import GOLDEN_DIR, Trace

PRESETS = sorted(SCENARIOS)


# ---------------------------------------------------------------------------
# Layer enum + bucket mapping
# ---------------------------------------------------------------------------

def test_every_phase_has_a_default_layer_and_bucket():
    for phase in Phase:
        layer = DEFAULT_LAYER[phase]
        bucket = loss_bucket(phase, layer)
        if phase is Phase.STEP:
            assert bucket is None          # productive, not a loss
        else:
            assert isinstance(bucket, str) and bucket


def test_loss_bucket_distinguishes_lost_causes():
    assert loss_bucket(Phase.LOST, Layer.HARDWARE) == "failure_rollback"
    assert loss_bucket(Phase.LOST, Layer.SCHEDULING) == "preemption_rollback"
    assert loss_bucket(Phase.INIT, Layer.COMPILER) == "compile"
    assert loss_bucket(Phase.INIT, Layer.SCHEDULING) == "migration_restart"


def test_unmapped_combination_falls_back_to_default_bucket():
    # DATA_STALL has no hardware-layer bucket: falls back to input_stall
    assert loss_bucket(Phase.DATA_STALL, Layer.HARDWARE) == "input_stall"


def test_layer_of_reads_tag_and_tolerates_legacy_values():
    assert layer_of({"layer": "compiler"}, Phase.INIT) is Layer.COMPILER
    # pre-refactor emitter tags ("fleet") fall back to the phase default
    assert layer_of({"layer": "fleet"}, Phase.LOST) is Layer.HARDWARE
    assert layer_of({}, Phase.IDLE) is Layer.SCHEDULING


# ---------------------------------------------------------------------------
# conservation on simulated fleets (every preset) and golden traces
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["reference", "vectorized"])
@pytest.mark.parametrize("preset", PRESETS)
def test_waterfall_conserves_on_every_preset(preset, engine):
    sim = golden_sim(preset, engine=engine)
    wf = AttributionWaterfall().attach(sim.ledger)
    sim.run()
    wf.assert_conserves(sim.ledger)        # bit-for-bit + exact partition
    totals = sim.ledger.totals()
    assert wf.n_events == totals["n_events"]
    checks = wf.conservation()
    assert checks["conserved"]
    # the report's loss rows + productive ideal account for capacity
    rep = wf.report()
    total = (rep["ideal_chip_time"]
             + sum(r["chip_time"] for r in rep["losses"]))
    assert total == pytest.approx(rep["capacity_chip_time"], rel=1e-12)


@pytest.mark.parametrize("preset", PRESETS)
def test_waterfall_from_golden_trace_conserves(preset):
    trace = Trace.load(GOLDEN_DIR / f"{preset}.jsonl")
    wf, ledger = waterfall_from_trace(trace)
    assert ledger.totals() == trace.totals     # replay is exact
    wf.assert_conserves(ledger)
    assert wf.totals_match(ledger)


def test_attribution_moves_with_the_scenario():
    """The waterfall localizes losses to the right layer: a maintenance
    wave grows the scheduling share, a failure storm the hardware share
    (vs the steady baseline)."""
    def shares(preset):
        sim = golden_sim(preset)
        wf = AttributionWaterfall().attach(sim.ledger)
        sim.run()
        rep = wf.report()
        cap = rep["capacity_chip_time"]
        return {k: v / cap for k, v in rep["lost_by_layer"].items()}

    steady = shares("steady")
    assert shares("maintenance")["scheduling"] > steady["scheduling"]
    assert (shares("failure_storm").get("hardware", 0.0)
            > steady.get("hardware", 0.0))


def test_preemption_rollback_lands_on_scheduling_layer():
    """LOST intervals carry the evicting cause: preemption rollbacks are
    scheduling-layer, not hardware-layer."""
    from repro.fleet.scenarios import build_sim

    sim = build_sim(SCENARIOS["steady"].load(1.6), n_jobs=40, seed=7,
                    n_pods=2, pod_size=64, horizon=24 * 3600.0,
                    retain_intervals=True)
    sim.run()
    preempted = sum(j.preemptions for j in sim.jobs.values())
    assert preempted > 0, "need preemptions to exercise the routing"
    lost_layers = {iv.segment["layer"] for iv in sim.intervals
                   if iv.phase is Phase.LOST}
    assert Layer.SCHEDULING.value in lost_layers


# ---------------------------------------------------------------------------
# conservation on arbitrary streams (hypothesis + example mirrors)
# ---------------------------------------------------------------------------

def _stream(seed, n):
    rng = random.Random(seed)
    phases = list(Phase)
    layers = [l.value for l in Layer] + [None, "fleet"]
    out = []
    for _ in range(n):
        t0 = rng.uniform(0, 40_000.0)
        seg = {"size_class": rng.choice(("small", "xl"))}
        layer = rng.choice(layers)
        if layer is not None:
            seg["layer"] = layer
        out.append(Interval(
            job_id=f"job{rng.randrange(6)}", phase=rng.choice(phases),
            t0=t0, t1=t0 + rng.uniform(0, 9_000.0),
            chips=rng.choice([1, 4, 64]), segment=seg))
    return out


def _assert_conserves_stream(seed, n, ingest="record"):
    led = GoodputLedger(capacity_chip_time=5e9, retain_intervals=False)
    wf = AttributionWaterfall().attach(led)
    pg_rng = random.Random(seed + 1)
    ivs = _stream(seed, n)
    pgs = [pg_rng.uniform(0.1, 1.0) for _ in ivs]
    if ingest == "record":
        for iv, pg in zip(ivs, pgs):
            led.record(iv, pg=pg)
    else:           # the vectorized engine's columnar path
        led.add_intervals([iv.job_id for iv in ivs],
                          [iv.phase for iv in ivs],
                          [iv.t0 for iv in ivs], [iv.t1 for iv in ivs],
                          [iv.chips for iv in ivs], pgs,
                          [iv.segment for iv in ivs])
    wf.assert_conserves(led)
    assert wf.totals_match(led)
    checks = wf.conservation()
    assert checks["cells_partition_allocated"]
    assert checks["capacity_covers_allocated"]
    return led


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=300),
       st.sampled_from(["record", "batch"]))
def test_waterfall_conserves_arbitrary_streams(seed, n, ingest):
    _assert_conserves_stream(seed, n, ingest)


@pytest.mark.parametrize("ingest", ["record", "batch"])
@pytest.mark.parametrize("seed", [0, 3, 11])
def test_waterfall_conserves_arbitrary_streams_examples(seed, ingest):
    _assert_conserves_stream(seed, 250, ingest)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=300))
def test_batched_ingest_totals_match_per_event(seed, n):
    # the ledger-level equivalence gate: columnar add_intervals must be
    # bit-for-bit the same accumulation as one record() per row
    a = _assert_conserves_stream(seed, n, "record")
    b = _assert_conserves_stream(seed, n, "batch")
    assert a.totals() == b.totals()


def test_misset_capacity_is_not_conserved():
    """A capacity smaller than allocation must fail conservation (the
    unallocated residual would go negative); a capacity-less ledger
    (RG-only use) skips the capacity checks and emits no unallocated
    row rather than a negative one."""
    led = GoodputLedger(capacity_chip_time=10.0, retain_intervals=False)
    wf = AttributionWaterfall().attach(led)
    led.emit("a", Phase.STEP, 0.0, 100.0, chips=1)     # allocated=100 > 10
    assert not wf.conservation()["capacity_covers_allocated"]
    assert not wf.conservation()["conserved"]
    with pytest.raises(AssertionError, match="conservation"):
        wf.assert_conserves(led)

    bare = GoodputLedger(retain_intervals=False)       # capacity never set
    wf2 = AttributionWaterfall().attach(bare)
    bare.emit("a", Phase.STEP, 0.0, 100.0, chips=1)
    wf2.assert_conserves(bare)
    buckets = [r["bucket"] for r in wf2.report()["losses"]]
    assert "unallocated_capacity" not in buckets


def test_attach_refuses_a_used_ledger():
    led = GoodputLedger()
    led.emit("a", Phase.STEP, 0.0, 10.0, chips=1)
    with pytest.raises(ValueError, match="before any event"):
        AttributionWaterfall().attach(led)


def test_waterfall_state_is_bounded():
    led = GoodputLedger(retain_intervals=False)
    wf = AttributionWaterfall().attach(led)
    for iv in _stream(0, 2000):
        led.record(iv)
    # cells are (layer, phase) pairs — bounded by the enums, not events
    assert sum(wf.state_size().values()) <= len(Layer) * len(Phase)


# ---------------------------------------------------------------------------
# keep_intervals opt-out (satellite)
# ---------------------------------------------------------------------------

def test_fleet_sim_keep_intervals_opt_out():
    from repro.fleet.sim import FleetSim, SimConfig

    cfg = SimConfig(n_pods=2, pod_size=32, horizon=3600.0)
    assert cfg.retain_intervals          # config default unchanged
    sim = FleetSim(cfg, keep_intervals=False)
    wf = AttributionWaterfall().attach(sim.ledger)
    sim.run()
    assert sim.ledger.intervals is None
    with pytest.raises(AttributeError):
        sim.intervals
    wf.assert_conserves(sim.ledger)
