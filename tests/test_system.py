"""End-to-end behaviour tests for the paper's system (deliverable c).

These exercise the whole stack together: sharded train step on a dev mesh,
sharding-rule invariants, optimization-lever equivalence, the HLO collective
parser on a freshly compiled module, and MPG accounting over a real
orchestrator run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import model
from repro.models.config import ShapeConfig


# ---------------------------------------------------------------------------
# sharded train step end-to-end (single CPU device as a 1x1 mesh)
# ---------------------------------------------------------------------------

def test_sharded_train_step_runs_and_learns():
    from repro.launch.mesh import make_dev_mesh
    from repro.launch.strategy import (init_train_state, jit_train_step)
    from repro.parallel.ctx import parallel_ctx

    cfg = get_smoke("granite-3-8b")
    mesh = make_dev_mesh(data=1, model=1)
    shape = ShapeConfig("t", "train", 64, 4)
    fn, _, ctx = jit_train_step(cfg, shape, mesh)
    state = init_train_state(cfg, jax.random.key(0), mesh)
    batch = model.synthetic_batch(cfg, shape, jax.random.key(1))
    batch = jax.tree.map(jnp.asarray, batch)
    with parallel_ctx(ctx):
        losses = []
        for i in range(5):
            state, metrics = fn(state, batch)
            losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]          # same batch: must memorize
    assert int(state["opt"]["step"]) == 5


def test_param_shardings_cover_tree():
    from repro.launch.mesh import make_dev_mesh
    from repro.parallel.sharding import param_shardings

    cfg = get_smoke("mixtral-8x7b")
    mesh = make_dev_mesh(data=1, model=1)
    sh = param_shardings(cfg, mesh)
    params = model.abstract_params(cfg)
    assert jax.tree.structure(sh) == jax.tree.structure(params)


def test_sharding_divisibility_fallback():
    """A dim not divisible by the mesh axis must replicate, not crash."""
    from repro.models.init import ParamSpec
    from repro.parallel.sharding import spec_to_pspec

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 16))

    spec = ParamSpec((10, 48), ("vocab", "embed"))   # 10 % 16 != 0
    p = spec_to_pspec(spec, FakeMesh())
    assert p[0] is None                               # vocab->model dropped
    assert p[1] == "data"


# ---------------------------------------------------------------------------
# optimization levers are numerically equivalent to the baseline
# ---------------------------------------------------------------------------

def test_loss_chunk_and_microbatch_equivalence():
    from repro.launch.strategy import make_train_step
    from repro.optim import AdamWConfig, adamw_init

    cfg0 = get_smoke("smollm-135m")
    params = model.init_params(cfg0, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (8, 64), 0, cfg0.vocab_size)
    batch = {"tokens": toks}

    def run(**kw):
        cfg = dataclasses.replace(cfg0, **kw)
        state = {"params": params, "opt": adamw_init(params)}
        step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
        state, m = step(state, batch)
        return float(m["loss"]), state["params"]

    base_loss, base_p = run()
    for kw in (dict(loss_chunk=16), dict(microbatches=4),
               dict(loss_chunk=16, microbatches=2)):
        loss, p = run(**kw)
        assert abs(loss - base_loss) < 1e-4, kw
        dp = max(float(jnp.max(jnp.abs(a - b)))
                 for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(base_p)))
        assert dp < 1e-4, kw


# ---------------------------------------------------------------------------
# HLO collective parser against a real compiled module
# ---------------------------------------------------------------------------

def test_while_trip_count_on_compiled_module():
    from repro.core import hlo_analysis

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c.T) @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile().as_text()
    trips = hlo_analysis.while_trip_counts(txt)
    assert any(t == 7 for _, t in trips), trips


def test_shape_bytes():
    from repro.core.hlo_analysis import shape_bytes

    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert shape_bytes("pred[]") == 1


# ---------------------------------------------------------------------------
# MPG end-to-end over a real (tiny) training run
# ---------------------------------------------------------------------------

def test_orchestrator_mpg_accounting(tmp_path):
    from repro.core.goodput import compute_goodput
    from repro.runtime.orchestrator import Orchestrator, RunConfig

    cfg = get_smoke("rwkv6-3b")
    orc = Orchestrator(cfg, RunConfig(steps=6, batch=2, seq=32,
                                      checkpoint_every=3,
                                      ckpt_dir=str(tmp_path)))
    orc.run()
    total = sum(i.chip_time for i in orc.intervals)
    rep = compute_goodput(orc.intervals, total)
    assert 0 < rep.rg <= 1
    assert total > 0
