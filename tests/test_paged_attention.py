"""Paged-attention decode kernel: equivalence against the pure-jnp gather
reference AND the model's dense ``decode_attention``, across mixed
lengths, GQA group sizes, and sliding windows; plus the block-size pin
that keeps allocator pages equal to kernel kv tiles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from tests._hypothesis_fallback import given, settings, st

from repro.kernels.paged_attention.ops import (DEFAULT_BLOCK_TOKENS,
                                               paged_attention_decode,
                                               resolve_impl)
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.serve.kv_cache import FLASH_ATTENTION_BLOCK_K, PagedKVCache


def _mk(seed, b, hq, hkv, d, n_pages, bt, nb, lengths):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    kp = jax.random.normal(ks[1], (hkv, n_pages, bt, d), jnp.float32)
    vp = jax.random.normal(ks[2], (hkv, n_pages, bt, d), jnp.float32)
    # distinct pages per row, shuffled so table order != page order
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_pages)[: b * nb].reshape(b, nb)
    bt_m = jnp.asarray(perm, jnp.int32)
    lens = jnp.asarray(lengths, jnp.int32)
    return q, kp, vp, bt_m, lens


# ---------------------------------------------------------------------------
# kernel == gather ref
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["kernel", "ref"])
@pytest.mark.parametrize("b,hq,hkv,d,bt,nb,window", [
    (4, 4, 2, 16, 8, 3, 0),      # GQA g=2, mixed lengths
    (3, 6, 3, 32, 16, 2, 0),     # g=2, wider head
    (2, 4, 4, 16, 8, 4, 0),      # MHA g=1
    (4, 8, 2, 16, 8, 3, 6),      # sliding window inside one page
    (3, 4, 1, 16, 8, 4, 20),     # window spanning pages, g=4
])
def test_paged_matches_ref(impl, b, hq, hkv, d, bt, nb, window):
    n_pages = b * nb + 1
    lengths = [(i * 7 + 3) % (nb * bt) + 1 for i in range(b)]
    lengths[0] = nb * bt             # one full row
    q, kp, vp, bt_m, lens = _mk(b + d, b, hq, hkv, d, n_pages, bt, nb,
                                lengths)
    out = paged_attention_decode(q, kp, vp, bt_m, lens, window=window,
                                 impl=impl, interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt_m, lens, window=window)
    np.testing.assert_allclose(out, ref, atol=3e-6)


def test_inactive_rows_output_exact_zeros():
    q, kp, vp, bt_m, lens = _mk(1, 4, 4, 2, 16, 13, 8, 3, [0, 5, 0, 17])
    for impl in ("kernel", "ref"):
        out = paged_attention_decode(q, kp, vp, bt_m, lens, impl=impl,
                                     interpret=True)
        assert np.all(np.asarray(out)[[0, 2]] == 0.0), impl
        assert np.all(np.isfinite(np.asarray(out)))


# ---------------------------------------------------------------------------
# kernel == the model's dense decode_attention (the serve-path oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["kernel", "ref"])
def test_paged_matches_dense_decode_attention(impl):
    from repro.models.attention import decode_attention

    b, hq, hkv, d, bt, nb = 3, 4, 2, 16, 8, 3
    lengths = [24, 9, 1]
    q, kp, vp, bt_m, lens = _mk(5, b, hq, hkv, d, b * nb, bt, nb, lengths)
    out = paged_attention_decode(q, kp, vp, bt_m, lens, impl=impl,
                                 interpret=True)
    # gather the pages back into the dense (b, S, hkv, d) cache layout:
    # table order is position order per the block-table ABI
    k_dense = np.asarray(kp)[:, np.asarray(bt_m)].transpose(1, 0, 2, 3, 4) \
        .reshape(b, hkv, nb * bt, d).transpose(0, 2, 1, 3)
    v_dense = np.asarray(vp)[:, np.asarray(bt_m)].transpose(1, 0, 2, 3, 4) \
        .reshape(b, hkv, nb * bt, d).transpose(0, 2, 1, 3)
    dense = decode_attention(q[:, None], jnp.asarray(k_dense),
                             jnp.asarray(v_dense), lens)[:, 0]
    np.testing.assert_allclose(out, dense, atol=3e-6)


# ---------------------------------------------------------------------------
# properties: mixed lengths x GQA x window, kernel == ref
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), g=st.sampled_from([1, 2, 4]),
       window=st.sampled_from([0, 5, 16]), bt=st.sampled_from([8, 16]))
def test_paged_attention_property(seed, g, window, bt):
    rng = np.random.default_rng(seed)
    b, hkv, d, nb = 4, 2, 16, 2
    hq = g * hkv
    lengths = rng.integers(0, nb * bt + 1, b).tolist()
    q, kp, vp, bt_m, lens = _mk(seed, b, hq, hkv, d, b * nb + 1, bt, nb,
                                lengths)
    out = paged_attention_decode(q, kp, vp, bt_m, lens, window=window,
                                 impl="kernel", interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt_m, lens, window=window)
    np.testing.assert_allclose(out, ref, atol=3e-6)


# ---------------------------------------------------------------------------
# pins
# ---------------------------------------------------------------------------

def test_kernel_kv_tile_pins_to_allocator_block_size():
    """Allocator pages ARE kernel kv tiles: the three constants that make
    block tables map 1:1 onto kernel grid iterations must stay equal."""
    assert DEFAULT_BLOCK_TOKENS == FLASH_ATTENTION_BLOCK_K
    assert PagedKVCache(1).block_tokens == DEFAULT_BLOCK_TOKENS


def test_resolve_impl_off_tpu_is_ref():
    assert resolve_impl("kernel") == "kernel"
    assert resolve_impl("ref") == "ref"
    if jax.default_backend() != "tpu":
        assert resolve_impl("auto") == "ref"
