"""Continuous-batching engine tests: paged KV-cache allocator, interval
partition/conservation properties, SLO-breach attribution, preemption
(LOST) accounting, determinism, and the continuous-vs-static A/B."""
import inspect
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from tests._hypothesis_fallback import given, settings, st

from repro.core.attribution import AttributionWaterfall
from repro.core.goodput import (ALLOCATED_PHASES, PRODUCTIVE_PHASES, Layer,
                                Phase, loss_bucket)
from repro.core.ledger import GoodputLedger
from repro.serve import (FLASH_ATTENTION_BLOCK_K, ContinuousServeEngine,
                         OutOfBlocksError, PagedKVCache, ServeRequest,
                         ServeSLO, SimulatedExecutor, run_static,
                         synthetic_requests)


# ---- paged KV cache ------------------------------------------------------

def test_kv_block_size_mirrors_flash_attention_block_k():
    """The allocator's default block granularity is the Pallas flash
    attention kernel's key-block tile, so paged decode over block tables
    feeds the kernel whole tiles."""
    from repro.kernels.flash_attention.flash_attention import flash_attention

    sig = inspect.signature(flash_attention)
    assert FLASH_ATTENTION_BLOCK_K == sig.parameters["block_k"].default
    assert PagedKVCache(n_blocks=2).block_tokens == FLASH_ATTENTION_BLOCK_K


def test_kv_allocate_append_free_roundtrip():
    kv = PagedKVCache(n_blocks=4, block_tokens=4)
    kv.allocate(7, 5)                      # 5 tokens -> 2 blocks
    assert kv.used_blocks == 2 and kv.free_blocks == 2
    assert kv.seq_len(7) == 5
    claimed = [kv.append_token(7) for _ in range(3)]   # tokens 6, 7, 8
    assert claimed == [False, False, False]            # block 2 has room
    assert kv.seq_len(7) == 8 and kv.used_blocks == 2
    assert kv.append_token(7) is True      # token 9 crosses the boundary
    assert kv.used_blocks == 3
    assert len(kv.block_table(7)) == 3
    kv.free(7)
    assert kv.used_blocks == 0 and kv.free_blocks == 4
    assert kv.stats.peak_blocks_used == 3
    assert kv.stats.frees == 1


def test_kv_block_tables_never_alias():
    kv = PagedKVCache(n_blocks=6, block_tokens=2)
    kv.allocate(1, 3)
    kv.allocate(2, 4)
    held = kv.block_table(1) + kv.block_table(2)
    assert len(held) == len(set(held)) == 4


def test_kv_allocation_is_lifo_deterministic():
    """Freed blocks return to the stack and are re-issued in reverse —
    same allocate/free sequence, same block tables, every run."""
    def run():
        kv = PagedKVCache(n_blocks=8, block_tokens=2)
        kv.allocate(1, 4)
        kv.allocate(2, 4)
        kv.free(1)
        kv.allocate(3, 6)
        return kv.block_table(3)

    assert run() == run()


def test_kv_exhaustion_raises_and_counts():
    kv = PagedKVCache(n_blocks=2, block_tokens=4)
    kv.allocate(1, 8)
    assert not kv.can_allocate(1)
    with pytest.raises(OutOfBlocksError):
        kv.allocate(2, 1)
    assert kv.stats.failed_allocations == 1
    with pytest.raises(OutOfBlocksError):
        kv.append_token(1)                 # token 9 needs a 3rd block


def test_kv_rejects_bad_arguments():
    kv = PagedKVCache(n_blocks=2, block_tokens=4)
    with pytest.raises(ValueError):
        PagedKVCache(n_blocks=0)
    with pytest.raises(ValueError):
        kv.allocate(1, 0)
    kv.allocate(1, 1)
    with pytest.raises(ValueError):
        kv.allocate(1, 1)                  # double-allocate same rid


# ---- SLO-breach phase wiring --------------------------------------------

def test_slo_breach_phase_is_allocated_scheduling_loss():
    assert Phase.SLO_BREACH in ALLOCATED_PHASES
    assert Phase.SLO_BREACH not in PRODUCTIVE_PHASES
    assert loss_bucket(Phase.SLO_BREACH, None) == "slo_breach"
    assert loss_bucket(Phase.SLO_BREACH, Layer.SCHEDULING) == "slo_breach"


# ---- engine accounting properties ---------------------------------------

def _capture(ledger):
    events = []
    ledger.subscribe_events(lambda iv, pg: events.append(iv))
    return events


def _run_engine(arrivals, max_new, n_slots, kv_blocks=None, slo=None,
                static_batch=None):
    ledger = GoodputLedger(window=60.0)
    events = _capture(ledger)
    reqs = [ServeRequest(rid=i, prompt_len=16, max_new=m, t_submit=t)
            for i, (t, m) in enumerate(zip(arrivals, max_new))]
    kwargs = {}
    if slo is not None:
        kwargs["slo"] = slo
    if static_batch is not None:
        rep = run_static(reqs, batch=static_batch,
                         executor=SimulatedExecutor(),
                         ledger=ledger, **kwargs)
    else:
        kv = (PagedKVCache(n_blocks=kv_blocks, block_tokens=8)
              if kv_blocks else None)
        eng = ContinuousServeEngine(n_slots, SimulatedExecutor(),
                                    kv_cache=kv, ledger=ledger, **kwargs)
        rep = eng.run(reqs)
    return rep, ledger, events


def _assert_partition(events, n_slots, span):
    """Supply-side intervals (everything but demand-side QUEUED) must
    cover every elementary segment of the engine's span with exactly
    n_slots chips — no gap, no overlap."""
    supply = [iv for iv in events if iv.phase is not Phase.QUEUED]
    cuts = sorted({*(iv.t0 for iv in supply), *(iv.t1 for iv in supply)})
    assert cuts[-1] - cuts[0] == pytest.approx(span)
    for lo, hi in zip(cuts, cuts[1:]):
        if hi <= lo:
            continue
        mid = (lo + hi) / 2
        cover = sum(iv.chips for iv in supply if iv.t0 <= mid < iv.t1)
        assert cover == n_slots, (
            f"[{lo}, {hi}) covered by {cover} chips, want {n_slots}")


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.floats(0.0, 5.0), st.integers(1, 12)),
                min_size=1, max_size=12),
       st.integers(1, 4))
def test_continuous_intervals_partition_capacity(jobs, n_slots):
    arrivals = sorted(t for t, _ in jobs)
    max_new = [m for _, m in jobs]
    rep, ledger, events = _run_engine(arrivals, max_new, n_slots)
    _assert_partition(events, n_slots, rep.span)
    # allocated chip-time == capacity exactly (the tiling, summed)
    tot = ledger.totals()
    assert math.isclose(tot["allocated_chip_time"],
                        rep.capacity_chip_time, rel_tol=1e-9)
    # ...and totals equal capacity minus accounted idle, i.e. busy time
    busy = sum(ledger.phase_chip_time(p) for p in ALLOCATED_PHASES
               if p is not Phase.IDLE)
    assert math.isclose(busy,
                        rep.capacity_chip_time
                        - ledger.phase_chip_time(Phase.IDLE),
                        rel_tol=1e-9, abs_tol=1e-12)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.floats(0.0, 5.0), st.integers(1, 8)),
                min_size=1, max_size=10),
       st.integers(1, 3))
def test_static_intervals_partition_capacity(jobs, batch):
    arrivals = sorted(t for t, _ in jobs)
    max_new = [m for _, m in jobs]
    rep, ledger, events = _run_engine(arrivals, max_new, batch,
                                      static_batch=batch)
    _assert_partition(events, batch, rep.span)
    tot = ledger.totals()
    assert math.isclose(tot["allocated_chip_time"],
                        rep.capacity_chip_time, rel_tol=1e-9)


@pytest.mark.parametrize("jobs,width", [
    ([(0.0, 1)], 1),                              # single one-token request
    ([(0.0, 5), (0.0, 5), (0.0, 5)], 2),          # contended slots
    ([(0.0, 8), (0.3, 2), (0.31, 6), (4.0, 3)], 2),   # arrival gap -> idle
    ([(0.0, 4), (0.0, 12), (0.1, 1), (2.5, 7), (2.5, 7)], 4),
])
def test_intervals_partition_capacity_examples(jobs, width):
    """Fixed mirrors of the hypothesis properties, so the tiling
    invariant stays enforced in environments without hypothesis."""
    arrivals = sorted(t for t, _ in jobs)
    max_new = [m for _, m in jobs]
    for static in (None, width):
        rep, ledger, events = _run_engine(arrivals, max_new, width,
                                          static_batch=static)
        _assert_partition(events, width, rep.span)
        assert math.isclose(ledger.totals()["allocated_chip_time"],
                            rep.capacity_chip_time, rel_tol=1e-9)


def test_engine_is_deterministic():
    """Same requests, same executor seed -> bit-identical ledger totals
    (the virtual-time engine never reads a wall clock)."""
    def run_once():
        arr = [0.0, 0.1, 0.5, 0.9, 2.0, 2.0]
        reqs = synthetic_requests(arr, prompt_len=32, max_new=(4, 20),
                                  seed=3)
        ledger = GoodputLedger(window=60.0)
        eng = ContinuousServeEngine(
            2, SimulatedExecutor(), ledger=ledger,
            kv_cache=PagedKVCache(n_blocks=8, block_tokens=16),
            slo=ServeSLO(ttft=0.3, tpot=0.02))
        eng.run(reqs)
        return ledger.totals()

    first, second = run_once(), run_once()
    assert first == second
    assert first["n_events"] > 0


def test_tokens_are_bit_identical_across_runs():
    arr = [0.0, 0.2, 0.4]
    a = synthetic_requests(arr, seed=1)
    b = synthetic_requests(arr, seed=1)
    ContinuousServeEngine(2, SimulatedExecutor()).run(a)
    ContinuousServeEngine(2, SimulatedExecutor()).run(b)
    assert [r.out_tokens for r in a] == [r.out_tokens for r in b]
    assert [r.token_times for r in a] == [r.token_times for r in b]


# ---- SLO tagging ---------------------------------------------------------

def _slo_run(slo):
    arr = [0.0] * 6
    reqs = synthetic_requests(arr, prompt_len=64, max_new=(10, 10), seed=0)
    ledger = GoodputLedger(window=60.0)
    wf = AttributionWaterfall()
    wf.attach(ledger)
    eng = ContinuousServeEngine(2, SimulatedExecutor(), slo=slo,
                                ledger=ledger)
    rep = eng.run(reqs)
    wf.assert_conserves(ledger)
    return rep, ledger, wf


def test_tight_slo_books_breach_time_as_scheduling_loss():
    rep, ledger, wf = _slo_run(ServeSLO(ttft=0.05, tpot=0.001))
    assert ledger.phase_chip_time(Phase.SLO_BREACH) > 0.0
    assert rep.tokens_within_slo < rep.tokens
    assert rep.slo_goodput < rep.goodput["RG"] * rep.goodput["SG"] + 1e-12
    buckets = {(r["layer"], r["bucket"])
               for r in wf.report(rep.capacity_chip_time)["losses"]}
    assert ("scheduling", "slo_breach") in buckets


def test_loose_slo_books_no_breach_time():
    rep, ledger, _ = _slo_run(ServeSLO(ttft=1e9, tpot=1e9))
    assert ledger.phase_chip_time(Phase.SLO_BREACH) == 0.0
    assert rep.tokens_within_slo == rep.tokens
    assert rep.slo_token_goodput == 1.0


# ---- preemption (paged-cache pressure) ----------------------------------

def test_preemption_under_kv_pressure_books_lost_and_conserves():
    """A cache small enough to overcommit forces recompute preemption:
    the victim's work re-books as LOST, the waterfall still balances,
    and every request still finishes with its full token budget."""
    arr = [0.0] * 8
    # one-block prompts: admission's full-need check passes for several
    # requests against the same free headroom, whose lazy decode growth
    # then collides — the overcommit that makes preemption reachable
    reqs = synthetic_requests(arr, prompt_len=8, max_new=(12, 24), seed=5)
    ledger = GoodputLedger(window=60.0)
    wf = AttributionWaterfall()
    wf.attach(ledger)
    eng = ContinuousServeEngine(
        4, SimulatedExecutor(), ledger=ledger,
        kv_cache=PagedKVCache(n_blocks=7, block_tokens=8))
    rep = eng.run(reqs)
    wf.assert_conserves(ledger)
    assert rep.preemptions > 0
    assert ledger.phase_chip_time(Phase.LOST) > 0.0
    assert all(len(r.out_tokens) == r.max_new for r in reqs)
    assert rep.kv_cache["failed_allocations"] > 0


def test_engine_rejects_request_larger_than_cache():
    kv = PagedKVCache(n_blocks=2, block_tokens=8)
    eng = ContinuousServeEngine(2, SimulatedExecutor(), kv_cache=kv)
    big = [ServeRequest(rid=0, prompt_len=20, max_new=8, t_submit=0.0)]
    with pytest.raises(ValueError, match="cache"):
        eng.run(big)


# ---- continuous vs static A/B -------------------------------------------

def test_continuous_beats_static_on_slo_tokens_at_equal_capacity():
    """The acceptance A/B at miniature scale: same requests, same slot
    count, same SLO — continuous batching's immediate detach/admit turns
    static's ride-out bubbles into on-time tokens."""
    arr = [0.05 * i for i in range(40)]
    slo = ServeSLO(ttft=0.5, tpot=0.05)

    cont = ContinuousServeEngine(4, SimulatedExecutor(), slo=slo).run(
        synthetic_requests(arr, prompt_len=64, max_new=(4, 32), seed=7))
    stat = run_static(
        synthetic_requests(arr, prompt_len=64, max_new=(4, 32), seed=7),
        batch=4, executor=SimulatedExecutor(), slo=slo)

    assert cont.n_slots == stat.n_slots == 4
    assert cont.tokens == stat.tokens          # same work delivered...
    assert cont.tokens_within_slo > stat.tokens_within_slo
    assert cont.slo_token_goodput > stat.slo_token_goodput


# ---- real-model executor -------------------------------------------------

def test_jax_slot_executor_serves_real_model_continuously():
    from repro.configs import get_smoke
    from repro.serve.jax_executor import JaxSlotExecutor

    cfg = get_smoke("smollm-135m")
    import numpy as np
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(rid=i, prompt_len=8, max_new=3,
                         t_submit=0.0,
                         prompt=rng.integers(0, cfg.vocab_size, 8)
                         .astype(np.int32))
            for i in range(3)]
    ledger = GoodputLedger(window=60.0)
    eng = ContinuousServeEngine(2, JaxSlotExecutor(cfg, max_len=16),
                                ledger=ledger, arch=cfg.name)
    rep = eng.run(reqs)
    assert rep.tokens == 9
    assert all(len(r.out_tokens) == 3 for r in reqs)
    assert all(r.t_done > r.t_first > 0.0 for r in reqs)
    assert rep.goodput["MPG"] > 0.0
    # slot caches are torn down on detach
    assert not eng.executor._caches
