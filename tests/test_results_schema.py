"""Benchmark output snapshots: every ``results/fleet/*.json`` is validated
against a schema (required keys, value types, unit ranges), so a benchmark
refactor cannot silently change the output shape the paper-figure
artifacts — and anything downstream of them — depend on.

The schema language is deliberately tiny (no external deps): a spec is a
dict of key -> checker, where a checker is a type, a tuple of types, a
callable, or a nested spec dict.  ``goodput_row`` is the shared shape for
one SG/RG/PG/MPG composition.
"""
import json
import pathlib

import pytest

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "fleet"
SERVE_RESULTS = RESULTS.parent / "serve"
REPO_ROOT = RESULTS.parents[1]


def unit(x):
    return isinstance(x, (int, float)) and 0.0 <= x <= 1.0


def positive(x):
    return isinstance(x, (int, float)) and x > 0


def non_negative(x):
    return isinstance(x, (int, float)) and x >= 0


def check(obj, spec, path=""):
    """Validate ``obj`` against ``spec``; returns a list of problems."""
    problems = []
    if isinstance(spec, dict):
        if not isinstance(obj, dict):
            return [f"{path}: expected dict, got {type(obj).__name__}"]
        for key, sub in spec.items():
            if key not in obj:
                problems.append(f"{path}.{key}: missing")
            else:
                problems += check(obj[key], sub, f"{path}.{key}")
    elif isinstance(spec, (type, tuple)):
        if not isinstance(obj, spec):
            problems.append(f"{path}: expected {spec}, "
                            f"got {type(obj).__name__}")
    elif callable(spec):
        if not spec(obj):
            problems.append(f"{path}: {spec.__name__} failed for {obj!r}")
    return problems


def each_value(spec):
    """Apply ``spec`` to every value of a (non-empty) dict."""
    def _each(obj):
        _each.problems = (
            [f"expected non-empty dict, got {type(obj).__name__}"]
            if not (isinstance(obj, dict) and obj) else
            [p for v in obj.values() for p in check(v, spec)])
        return not _each.problems
    _each.__name__ = f"each_value({getattr(spec, '__name__', spec)})"
    return _each


GOODPUT_ROW = {"SG": unit, "RG": unit, "PG": unit, "MPG": unit}

SCHEMAS = {
    "fig4_job_sizes.json": {
        "allocation_share_by_quarter":
            lambda x: isinstance(x, list) and len(x) >= 2
            and all(unit(v) for q in x for v in q.values()),
    },
    "fig12_pg_compiler.json": {
        "n_workloads": positive, "mean_pg_before": unit,
        "mean_pg_after": unit, "pg_uplift": positive,
        "workloads_improved": non_negative,
    },
    "fig14_rg_optimizations.json": {
        "rg_speedup_vs_baseline": each_value(positive),
        "baseline_rg": unit,
    },
    "fig15_rg_phases.json": {
        "rg_by_month": each_value(
            lambda x: isinstance(x, list) and all(unit(v) for v in x)),
    },
    "fig16_sg_by_size.json": {
        "sg_by_size": each_value(unit),
        "sg_overall": unit,
        "preemptions_by_size": each_value(non_negative),
        "policy_sweep": each_value({"sg_overall": unit}),
    },
    "ledger_scale.json": {
        "jobs": positive, "clusters": positive,
        "events_streamed": positive,
        "retained_state_entries": positive,
        "state_size": {"retained_intervals": lambda x: x == 0},
        # attribution rides the same stream without an interval list
        "attribution": {
            "state_entries": lambda x: 0 < x < 100,
            "conserved": lambda x: x is True,
            "lost_by_layer": each_value(non_negative),
        },
    },
    "table2_mpg_composition.json": {
        "table": each_value(GOODPUT_ROW),
        "checks": each_value(lambda x: isinstance(x, bool)),
    },
    "scenario_sweep.json": {
        "scale": str, "seed": int,
        "policies": each_value(
            {"placement": str, "preemption": str, "defrag": str}),
        "scenarios": each_value(each_value({
            **GOODPUT_ROW,
            "preemptions": non_negative, "xl_preemptions": non_negative,
            "failures": non_negative, "ledger_events": positive})),
        "checks": {
            # structural invariants must hold at any scale; directional
            # comparisons (maintenance_lowers_sg, ...) are recorded data
            "n_scenarios": lambda x: x >= 6,
            "n_policy_combos": lambda x: x >= 3,
            "all_bounded": lambda x: x is True,
            "protect_xl_never_evicts_xl": lambda x: x is True,
            "static_never_preempts": lambda x: x is True,
        },
    },
    "advisor_rank.json": {
        "scale": str,
        "knob_catalog": lambda x: isinstance(x, list) and len(x) >= 5,
        "scenarios": each_value({
            "baseline": GOODPUT_ROW,
            "conserved": lambda x: x is True,
            "lost_by_layer": each_value(non_negative),
            "ranking": lambda x: isinstance(x, list) and len(x) >= 5
            and all({"knob", "targets", "MPG", "recovered_mpg"} <= set(r)
                    for r in x),
        }),
        "checks": {
            # the PR acceptance matrix: >= 5 knobs on all 7 presets,
            # exact conservation everywhere, Fig 14 order on steady
            "n_scenarios": lambda x: x >= 7,
            "n_knobs": lambda x: x >= 5,
            "all_conserved": lambda x: x is True,
            "fig14_async_leads": lambda x: x is True,
            "policy_swap_noop_on_paper_baseline": lambda x: x is True,
            "gen_upgrade_pays_on_hetero": lambda x: x is True,
        },
    },
}


# paged KV allocator counters (repro.serve.kv_cache.KVCacheStats)
KV_CACHE = {
    "n_blocks": positive, "block_tokens": positive,
    "peak_blocks_used": non_negative, "allocations": non_negative,
    "block_appends": non_negative, "frees": non_negative,
    "failed_allocations": non_negative,
}


def kv_stats_or_none(x):
    """Continuous engines report allocator stats; static reserves
    per-slot dense caches and reports None."""
    return x is None or not check(x, KV_CACHE)


# one engine's metrics inside a serve_scale section (continuous/static)
SERVE_ENGINE_ROW = {
    "engine": str, "n_slots": positive, "requests": positive,
    "tokens": positive, "tokens_within_slo": non_negative,
    "slo_token_goodput": unit, "slo_goodput": unit,
    "preemptions": non_negative, "span": positive,
    "capacity_chip_time": positive,
    "goodput": GOODPUT_ROW,
    "ttft_s": {"mean": non_negative, "p50": non_negative,
               "p99": non_negative},
    "tpot_s": {"mean": non_negative, "p50": non_negative,
               "p99": non_negative},
    "rg_breakdown": each_value(unit),
    "kv_cache": kv_stats_or_none,
}

# every section of results/serve/serve_scale.json (and the committed
# BENCH_serve.json sections) is one equal-capacity A/B
SERVE_AB_SECTION = {
    "config": {"requests": positive, "span": positive, "n_slots": positive,
               "arrival": str, "slo_ttft": positive, "slo_tpot": positive,
               "seed": int},
    "config_fingerprint": str,
    "continuous": SERVE_ENGINE_ROW,
    "static": SERVE_ENGINE_ROW,
    # the PR acceptance invariant, shape-checked on every committed run:
    # continuous must beat static on tokens delivered within SLO
    "slo_tokens_margin": positive,
    "slo_token_goodput_margin": positive,
}

# one executor arm of a batched paged-decode A/B section
BATCHED_ARM = {
    "executor": str, "decode_tokens": positive, "decode_s": non_negative,
    "decode_calls": positive, "decode_tokens_per_s": positive,
    "tokens": positive, "requests": positive, "bench_wall_s": non_negative,
}

# the real-model batched paged-decode A/B (benchmarks/serve_scale.py
# run_batched_section): JaxBatchedExecutor vs JaxSlotExecutor over an
# identical request stream through the same continuous engine
BATCHED_AB_SECTION = {
    "config": {"arch": str, "requests": positive, "n_slots": positive,
               "max_len": positive, "attn_impl": str, "seed": int},
    "config_fingerprint": str,
    "per_slot": BATCHED_ARM,
    "batched": {**BATCHED_ARM,
                "decode_compiles": lambda x: x == 1,
                "kv_cache": KV_CACHE},
    "decode_tokens_per_s_ratio": positive,
    # the PR acceptance invariant: batching must not change a single token
    "tokens_identical": lambda x: x is True,
}


def ab_or_batched_section(x):
    """serve_scale.json holds two section shapes: the simulated
    continuous-vs-static A/B and the real-model batched paged-decode
    A/B, distinguished by their headline metric."""
    spec = (BATCHED_AB_SECTION
            if isinstance(x, dict) and "decode_tokens_per_s_ratio" in x
            else SERVE_AB_SECTION)
    return not check(x, spec)


PAGED_DECODE_POINT = {
    "width": positive, "seq_len": positive, "iters": positive,
    "per_slot_tokens_per_s": positive, "batched_tokens_per_s": positive,
    "ratio": positive,
}

SERVE_SCHEMAS = {
    "serve_scale.json": each_value(ab_or_batched_section),
    "paged_decode.json": {
        "arch": str, "attn_impl": str, "block_tokens": positive,
        "sweep": lambda x: isinstance(x, list) and len(x) >= 2
        and not [p for pt in x for p in check(pt, PAGED_DECODE_POINT)],
        "checks": {"n_points": lambda x: x >= 2,
                   "batched_wins_at_width_ge_4": lambda x: x is True},
    },
}


def test_every_fleet_result_has_a_schema():
    files = sorted(p.name for p in RESULTS.glob("*.json"))
    assert files, f"no benchmark outputs under {RESULTS}"
    unschema = [f for f in files if f not in SCHEMAS]
    assert not unschema, (
        f"results/fleet file(s) without a schema: {unschema} — add one to "
        "tests/test_results_schema.py so refactors can't silently change "
        "their shape")


@pytest.mark.parametrize("name", sorted(SCHEMAS))
def test_fleet_result_matches_schema(name):
    path = RESULTS / name
    if not path.exists():
        pytest.skip(f"{name} not generated in this checkout")
    problems = check(json.loads(path.read_text()), SCHEMAS[name], name)
    assert not problems, "\n".join(problems)


def test_every_serve_result_has_a_schema():
    files = sorted(p.name for p in SERVE_RESULTS.glob("*.json")) \
        if SERVE_RESULTS.exists() else []
    unschema = [f for f in files if f not in SERVE_SCHEMAS]
    assert not unschema, (
        f"results/serve file(s) without a schema: {unschema} — add one to "
        "tests/test_results_schema.py so refactors can't silently change "
        "their shape")


@pytest.mark.parametrize("name", sorted(SERVE_SCHEMAS))
def test_serve_result_matches_schema(name):
    path = SERVE_RESULTS / name
    if not path.exists():
        pytest.skip(f"{name} not generated in this checkout")
    problems = check(json.loads(path.read_text()), SERVE_SCHEMAS[name], name)
    assert not problems, "\n".join(problems)


def test_committed_serve_bench_has_continuous_ahead():
    """PR acceptance: the committed BENCH_serve.json shows continuous
    beating static on within-SLO tokens at equal capacity, in every
    section."""
    path = REPO_ROOT / "BENCH_serve.json"
    if not path.exists():
        pytest.skip("BENCH_serve.json not committed in this checkout")
    bench = json.loads(path.read_text())
    sections = {k: v for k, v in bench.items()
                if isinstance(v, dict) and "slo_tokens_margin" in v}
    assert "tiny" in sections
    for name, section in sections.items():
        problems = check(section, SERVE_AB_SECTION, f"BENCH_serve.{name}")
        assert not problems, "\n".join(problems)
        c, s = section["continuous"], section["static"]
        assert c["n_slots"] == s["n_slots"]          # equal capacity
        assert c["tokens"] == s["tokens"]            # equal work
        assert c["tokens_within_slo"] > s["tokens_within_slo"], name


def test_committed_serve_bench_shows_batched_decode_win():
    """PR acceptance: the committed BENCH_serve.json's batched
    paged-decode sections are token-identical to per-slot decode with a
    single decode compile, and the full-width section shows the batching
    win (decode tokens/s ratio > 1 at width >= 4)."""
    path = REPO_ROOT / "BENCH_serve.json"
    if not path.exists():
        pytest.skip("BENCH_serve.json not committed in this checkout")
    bench = json.loads(path.read_text())
    sections = {k: v for k, v in bench.items()
                if isinstance(v, dict) and "decode_tokens_per_s_ratio" in v}
    assert {"batched_tiny", "batched_full"} <= set(sections)
    for name, sec in sections.items():
        problems = check(sec, BATCHED_AB_SECTION, f"BENCH_serve.{name}")
        assert not problems, "\n".join(problems)
        assert sec["tokens_identical"] is True, name
        assert sec["per_slot"]["tokens"] == sec["batched"]["tokens"], name
    full = sections["batched_full"]
    assert full["config"]["n_slots"] >= 4
    assert full["decode_tokens_per_s_ratio"] > 1.0


RESILIENCE_ARM = {
    **GOODPUT_ROW,
    "failures": int,
    "preemptions": int,
    "reshard_chip_time": non_negative,
    "gang_stall_chip_time": non_negative,
    "lost_by_layer": each_value(non_negative),
    "wall_s": non_negative,
}

RESILIENCE_PRESET = {
    "rigid": RESILIENCE_ARM,
    "elastic": RESILIENCE_ARM,
    # the PR acceptance invariant: elastic recovers MPG over rigid at
    # equal capacity, checked per committed section below
    "recovered_mpg": float,
    "recovered_by_layer": dict,
}

RESILIENCE_SECTION = {
    "config": {"n_jobs": positive, "seed": int, "n_pods": positive,
               "pod_size": positive, "horizon_days": positive,
               "slice_repair_s": positive, "target_load": positive},
    "config_fingerprint": str,
    "failure_storm": RESILIENCE_PRESET,
    "maintenance": RESILIENCE_PRESET,
}


def test_committed_resilience_bench_shows_elastic_recovery():
    """PR acceptance: the committed BENCH_resilience.json shows elastic
    recovering MPG over rigid on the failure_storm AND maintenance
    presets at equal capacity, in every section, with the loss moves
    attributed per layer; the tiny section also pins cross-engine
    equivalence under the repair window."""
    path = REPO_ROOT / "BENCH_resilience.json"
    if not path.exists():
        pytest.skip("BENCH_resilience.json not committed in this checkout")
    bench = json.loads(path.read_text())
    sections = {k: v for k, v in bench.items()
                if isinstance(v, dict) and "config_fingerprint" in v}
    assert "tiny" in sections
    for name, section in sections.items():
        problems = check(section, RESILIENCE_SECTION,
                         f"BENCH_resilience.{name}")
        assert not problems, "\n".join(problems)
        for preset in ("failure_storm", "maintenance"):
            p = section[preset]
            assert p["recovered_mpg"] > 0, (name, preset)
            assert p["recovered_mpg"] == pytest.approx(
                p["elastic"]["MPG"] - p["rigid"]["MPG"], abs=1e-6)
            # the mechanism, visible in the loss buckets: only the rigid
            # arm stalls surviving gang slices, only the elastic arm pays
            # reshard transfers
            assert p["elastic"]["reshard_chip_time"] > 0, (name, preset)
            assert p["elastic"]["gang_stall_chip_time"] == 0, (name, preset)
            assert p["rigid"]["reshard_chip_time"] == 0, (name, preset)
    assert bench["tiny"]["failure_storm"]["equivalence"]["engines_identical"]
    assert bench["tiny"]["maintenance"]["equivalence"]["engines_identical"]
    # the advisor section ranks the resiliency knobs on the same preset
    adv = bench.get("advisor")
    if adv:
        assert {r["knob"] for r in adv["ranking"]} == \
            {"elastic_resize", "multi_slice_gang"}


CONTROLLER_ARM = {
    **GOODPUT_ROW,
    "failures": int,
    "lost_by_layer": each_value(non_negative),
    "wall_s": non_negative,
}

CONTROLLER_PRESET = {
    "rigid": CONTROLLER_ARM,
    "elastic": CONTROLLER_ARM,
    "controlled": {**CONTROLLER_ARM,
                   "switches": list,
                   "policy_switch_chip_time": non_negative},
    "oracle_static": lambda x: x in ("rigid", "elastic"),
    "best_static_mpg": unit,
    "regret_mpg": float,
    "recovered_by_layer": dict,
}

CONTROLLER_SECTION = {
    "config": {"n_jobs": positive, "seed": int, "n_pods": positive,
               "pod_size": positive, "horizon_days": positive,
               "slice_repair_s": positive, "target_load": positive},
    "config_fingerprint": str,
    "summary": {
        "avg_mpg": {"rigid": unit, "elastic": unit, "controlled": unit},
        "best_static_arm": lambda x: x in ("rigid", "elastic"),
        "controller_beats_best_static_avg": lambda x: isinstance(x, bool),
        "max_regret_mpg": float,
    },
}

ADVERSARIAL_ROW = {
    "name": str,
    "genome": dict,
    "controlled_mpg": unit,
    "rigid_mpg": unit,
    "elastic_mpg": unit,
    "best_static_mpg": unit,
    "controller_survives": lambda x: isinstance(x, bool),
    "n_switches": non_negative,
}


def test_committed_controller_bench_passes_the_acceptance_gates():
    """PR acceptance on the committed BENCH_controller.json: (a) regret
    vs the per-preset best static policy <= 5% MPG on all 7 presets in
    every section, (b) the controlled average strictly above the best
    single static arm's average, and (c) the controller surviving every
    adversarially-searched scenario at or above the best static's MPG —
    with switch overhead attributed and cross-engine equivalence pinned
    in the tiny section."""
    path = REPO_ROOT / "BENCH_controller.json"
    if not path.exists():
        pytest.skip("BENCH_controller.json not committed in this checkout")
    bench = json.loads(path.read_text())
    sections = {k: v for k, v in bench.items()
                if isinstance(v, dict) and "summary" in v}
    assert "tiny" in sections
    presets = ("steady", "diurnal", "bursty", "maintenance",
               "failure_storm", "hetero_fleet", "peak_week")
    for name, section in sections.items():
        problems = check(section, CONTROLLER_SECTION,
                         f"BENCH_controller.{name}")
        for preset in presets:
            problems += check(section[preset], CONTROLLER_PRESET,
                              f"BENCH_controller.{name}.{preset}")
        assert not problems, "\n".join(problems)
        for preset in presets:
            p = section[preset]
            # gate (a): bounded regret vs the per-scenario oracle
            assert p["regret_mpg"] <= 0.05, (name, preset)
            assert p["best_static_mpg"] == \
                max(p["rigid"]["MPG"], p["elastic"]["MPG"])
        # gate (b): adapting beats committing to one static policy
        summary = section["summary"]
        assert summary["controller_beats_best_static_avg"] is True, name
        best = summary["avg_mpg"][summary["best_static_arm"]]
        assert summary["avg_mpg"]["controlled"] > best, name
    # controlled runs are bit-identical across engines (tiny section)
    for preset in presets:
        assert bench["tiny"][preset]["equivalence"]["engines_identical"]
    # gate (c): the committed adversarial suite never drives the
    # controller below the best static floor
    adv = bench["adversarial"]
    assert len(adv["suite"]) >= 3
    for row in adv["suite"]:
        problems = check(row, ADVERSARIAL_ROW,
                         f"BENCH_controller.adversarial.{row.get('name')}")
        assert not problems, "\n".join(problems)
        assert row["controller_survives"] is True, row["name"]
        assert row["controlled_mpg"] >= row["best_static_mpg"], row["name"]
        assert row["best_static_mpg"] == \
            max(row["rigid_mpg"], row["elastic_mpg"])


def test_scenario_sweep_covers_the_acceptance_matrix():
    """PR acceptance: >= 6 scenarios x 3 policy combos in the artifact."""
    path = RESULTS / "scenario_sweep.json"
    if not path.exists():
        pytest.skip("scenario_sweep.json not generated in this checkout")
    d = json.loads(path.read_text())
    assert len(d["scenarios"]) >= 6
    assert all(len(by_policy) >= 3 for by_policy in d["scenarios"].values())
