"""Streaming GoodputLedger tests: golden equivalence against the legacy
list-based computation, windowed-series conservation, segment reports,
subscriber hooks, and O(state) memory behaviour."""
import random

import pytest

from repro.core.goodput import (ALLOCATED_PHASES, PRODUCTIVE_PHASES,
                                Interval, Phase, compute_goodput,
                                rg_breakdown, segment_goodput)
from repro.core.ledger import GoodputLedger

ARCHES = ("smollm-135m", "mixtral-8x7b", "whisper-medium")
SIZES = ("small", "medium", "large", "xl")


def _random_stream(n=400, seed=0, horizon=50_000.0):
    """A messy but valid interval stream: every phase, several jobs,
    several segment tags, intervals crossing window boundaries."""
    rng = random.Random(seed)
    phases = list(Phase)
    ivs = []
    for i in range(n):
        t0 = rng.uniform(0, horizon)
        t1 = t0 + rng.uniform(0.0, horizon / 10)
        job = f"job{rng.randrange(12)}"
        ivs.append(Interval(
            job_id=job, phase=rng.choice(phases), t0=t0, t1=t1,
            chips=rng.choice([1, 4, 16, 256]),
            segment={"arch": rng.choice(ARCHES),
                     "size_class": rng.choice(SIZES)}))
    pg = {f"job{j}": rng.uniform(0.2, 0.9) for j in range(12)}
    return ivs, pg


def _legacy_goodput(intervals, capacity, pg_by_job=None):
    """The original whole-list computation, kept verbatim as the golden
    reference so the streaming path is checked against independent code."""
    allocated = productive = ideal = 0.0
    for iv in intervals:
        if iv.phase in ALLOCATED_PHASES:
            allocated += iv.chip_time
        if iv.phase in PRODUCTIVE_PHASES:
            productive += iv.chip_time
            ideal += iv.chip_time * (pg_by_job or {}).get(iv.job_id, 1.0)
    sg = allocated / capacity if capacity else 0.0
    rg = productive / allocated if allocated else 0.0
    pg = ideal / productive if productive else 0.0
    return sg, rg, pg


# ---------------------------------------------------------------------------
# golden equivalence: streaming == batch == legacy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 7])
def test_ledger_matches_legacy_batch(seed):
    ivs, pg = _random_stream(seed=seed)
    cap = 5e9
    led = GoodputLedger(capacity_chip_time=cap, retain_intervals=False)
    led.extend(ivs, pg_by_job=pg)
    rep = led.report()
    sg, rg, pgv = _legacy_goodput(ivs, cap, pg)
    assert rep.sg == pytest.approx(sg)
    assert rep.rg == pytest.approx(rg)
    assert rep.pg == pytest.approx(pgv)
    # and the wrapper API agrees with itself
    wrapped = compute_goodput(ivs, cap, pg)
    assert wrapped.mpg == pytest.approx(rep.mpg)


def test_report_time_pg_table_equals_streamed_pg():
    """pg supplied per-event at record() == pg supplied as a table at
    report() — the two API shapes must not drift."""
    ivs, pg = _random_stream(seed=3)
    streamed = GoodputLedger(retain_intervals=False)
    for iv in ivs:
        streamed.record(iv, pg=pg.get(iv.job_id, 1.0))
    tabled = GoodputLedger(retain_intervals=False)
    tabled.extend(ivs)     # default pg=1.0 at record time
    cap = 1e9
    assert streamed.report(cap).pg == pytest.approx(
        tabled.report(cap, pg_by_job=pg).pg)


def test_segment_report_matches_legacy():
    ivs, pg = _random_stream(seed=5)
    caps = {a: 1e9 for a in ARCHES}
    led = GoodputLedger(retain_intervals=False)
    led.extend(ivs, pg_by_job=pg)
    by_stream = led.segment_report("arch", caps)
    by_legacy = segment_goodput(ivs, "arch", caps, pg)
    assert set(by_stream) == set(by_legacy)
    for arch in by_stream:
        assert by_stream[arch].sg == pytest.approx(by_legacy[arch].sg)
        assert by_stream[arch].rg == pytest.approx(by_legacy[arch].rg)
        assert by_stream[arch].pg == pytest.approx(by_legacy[arch].pg)


def test_rg_breakdown_matches_legacy():
    ivs, _ = _random_stream(seed=6)
    led = GoodputLedger(retain_intervals=False)
    led.extend(ivs)
    bd = led.rg_breakdown()
    legacy = rg_breakdown(ivs)
    assert bd == pytest.approx(legacy)
    assert sum(bd.values()) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# windowed time series
# ---------------------------------------------------------------------------

def test_windowed_series_sums_to_aggregate():
    """Splitting intervals across windows must conserve chip-time: the
    per-window allocated/productive/ideal sums add up to the aggregate."""
    ivs, pg = _random_stream(seed=9)
    led = GoodputLedger(window=3600.0, retain_intervals=False)
    led.extend(ivs, pg_by_job=pg)
    series = led.series(capacity_chips=2048)
    rep = led.report(1.0)
    assert sum(w["allocated_chip_time"] for w in series) == pytest.approx(
        rep.allocated_chip_time)
    assert sum(w["productive_chip_time"] for w in series) == pytest.approx(
        rep.productive_chip_time)
    assert sum(w["ideal_chip_time"] for w in series) == pytest.approx(
        rep.ideal_chip_time)


def test_window_boundary_split():
    """One interval straddling 3 hourly windows lands proportionally."""
    led = GoodputLedger(window=3600.0)
    led.emit("a", Phase.STEP, t0=1800.0, t1=9000.0, chips=2)
    series = led.series(capacity_chips=2)
    assert len(series) == 3
    assert series[0]["productive_chip_time"] == pytest.approx(1800 * 2)
    assert series[1]["productive_chip_time"] == pytest.approx(3600 * 2)
    assert series[2]["productive_chip_time"] == pytest.approx(1800 * 2)
    # middle window is fully productive at capacity
    assert series[1]["sg"] == pytest.approx(1.0)
    assert series[1]["rg"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# sink mechanics
# ---------------------------------------------------------------------------

def test_subscriber_hook_sees_every_event():
    ivs, _ = _random_stream(seed=11, n=50)
    seen = []
    led = GoodputLedger(retain_intervals=False)
    led.subscribe(seen.append)
    led.extend(ivs)
    kept = [iv for iv in ivs if iv.chip_time > 0]
    assert len(seen) == len(kept) == led.n_events


def test_no_interval_materialization():
    ivs, _ = _random_stream(seed=13, n=1000)
    led = GoodputLedger(retain_intervals=False)
    led.extend(ivs)
    assert led.intervals is None
    state = led.state_size()
    assert state["retained_intervals"] == 0
    # accumulator state is bounded by jobs/segments/windows, not events
    assert sum(state.values()) < led.n_events / 2


def test_zero_and_negative_length_intervals_ignored():
    led = GoodputLedger()
    led.emit("a", Phase.STEP, t0=10.0, t1=10.0, chips=4)
    led.emit("a", Phase.STEP, t0=10.0, t1=5.0, chips=4)
    assert led.n_events == 0
    assert led.report(100.0).rg == 0.0


def test_multi_emitter_shared_capacity():
    """Two emitters share one ledger: capacities add, streams merge."""
    led = GoodputLedger()
    led.add_capacity(1000.0)
    led.add_capacity(3000.0)
    led.emit("sim_job", Phase.STEP, 0.0, 100.0, chips=10)
    led.emit("orc_job", Phase.IDLE, 0.0, 100.0, chips=10)
    rep = led.report()
    assert rep.capacity_chip_time == 4000.0
    assert rep.sg == pytest.approx(0.5)
    assert rep.rg == pytest.approx(0.5)
