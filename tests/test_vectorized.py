"""Vectorized event-core equivalence suite.

The vectorized engine's claim is "same decisions, same rng draws, same
ledger stream — faster".  Golden traces pin it byte-for-byte on the
golden configs (``test_golden_traces``); this module widens the net:

  * cross-engine ``ledger.totals()`` equality (plain ``==``, bit-for-bit)
    on every scenario preset and on non-default policy combinations —
    including the policies the fast paths special-case (best_fit) and the
    ones they must fall through for (spread, none);
  * the columnar ledger path: ``add_intervals`` vs per-event ``record``,
    batch-aware vs legacy subscribers, zero-row filtering;
  * the new ``SimConfig`` knobs (``engine``, ``sample_dt``) validate.
"""
import dataclasses

import pytest

from repro.core.goodput import Interval, Phase
from repro.core.ledger import GoodputLedger, IntervalBatch
from repro.fleet.scenarios import SCENARIOS, build_sim, golden_sim
from repro.fleet.sim import FleetSim, SimConfig
from repro.fleet.vectorized import VectorizedFleetSim

PRESETS = sorted(SCENARIOS)


# ---------------------------------------------------------------------------
# engine dispatch + config validation
# ---------------------------------------------------------------------------

def test_fleet_sim_dispatches_on_engine():
    ref = FleetSim(SimConfig(n_pods=2, pod_size=32, horizon=3600.0,
                             engine="reference"))
    vec = FleetSim(SimConfig(n_pods=2, pod_size=32, horizon=3600.0))
    assert type(ref) is FleetSim
    assert type(vec) is VectorizedFleetSim
    assert isinstance(vec, FleetSim)    # one behaviour contract


def test_engine_validates():
    with pytest.raises(ValueError, match="engine"):
        SimConfig(n_pods=2, pod_size=32, horizon=3600.0, engine="turbo")


@pytest.mark.parametrize("bad", [0.0, -1.0, float("nan")])
def test_sample_dt_validates(bad):
    with pytest.raises(ValueError, match="sample_dt"):
        SimConfig(n_pods=2, pod_size=32, horizon=3600.0, sample_dt=bad)


def test_sample_dt_sets_telemetry_cadence_without_touching_the_ledger():
    def run(sample_dt):
        sim = build_sim(SCENARIOS["steady"], n_jobs=30, seed=3, n_pods=2,
                        pod_size=64, horizon=86400.0, sample_dt=sample_dt)
        sim.run()
        return sim
    coarse, fine = run(6 * 3600.0), run(3600.0)
    assert len(fine.telemetry) > len(coarse.telemetry)
    assert fine.ledger.totals() == coarse.ledger.totals()


# ---------------------------------------------------------------------------
# cross-engine equivalence: every preset, non-default policy combos
# ---------------------------------------------------------------------------

def _totals(sim):
    sim.run()
    return sim.ledger.totals()


@pytest.mark.parametrize("preset", PRESETS)
def test_cross_engine_totals_bit_identical_on_presets(preset):
    ref = _totals(golden_sim(preset, engine="reference"))
    vec = _totals(golden_sim(preset, engine="vectorized"))
    assert vec == ref       # plain ==: every float bit-for-bit


# the fast paths special-case the builtin defaults (best_fit placement,
# protect_xl preemption, drain_for_xl defrag); every other combination
# must fall through to reference behaviour — same bits either way
POLICY_COMBOS = [
    ("first_fit", "protect_xl", "drain_for_xl"),
    ("spread", "priority_only", "migrate_small"),
    ("best_fit", "none", "none"),
    ("best_fit", "priority_only", "drain_for_xl"),
]


@pytest.mark.parametrize("placement,preemption,defrag", POLICY_COMBOS)
def test_cross_engine_totals_bit_identical_across_policies(
        placement, preemption, defrag):
    def totals(engine):
        sim = build_sim(SCENARIOS["bursty"], n_jobs=60, seed=11, n_pods=3,
                        pod_size=64, horizon=3 * 86400.0, engine=engine,
                        placement=placement, preemption=preemption,
                        defrag=defrag)
        return _totals(sim)
    assert totals("vectorized") == totals("reference")


def test_vectorized_is_default_and_survives_config_replace():
    cfg = SimConfig(n_pods=2, pod_size=32, horizon=3600.0)
    assert cfg.engine == "vectorized"
    # advisor-style sweeps rebuild configs via dataclasses.replace and
    # must keep riding the fast engine
    assert type(FleetSim(dataclasses.replace(cfg, seed=9))) \
        is VectorizedFleetSim


# ---------------------------------------------------------------------------
# columnar ledger path
# ---------------------------------------------------------------------------

def _rows(n, t_start=0.0):
    rows = []
    for i in range(n):
        t0 = t_start + 37.0 * i
        rows.append((f"job{i % 5}", list(Phase)[i % len(Phase)],
                     t0, t0 + 11.0 + i, 1 << (i % 5),
                     0.25 + 0.05 * (i % 7), {"size_class": "small"}))
    return rows


def _columns(rows):
    return ([r[0] for r in rows], [r[1] for r in rows],
            [r[2] for r in rows], [r[3] for r in rows],
            [r[4] for r in rows], [r[5] for r in rows],
            [r[6] for r in rows])


def test_add_intervals_matches_per_event_record():
    rows = _rows(64)
    a = GoodputLedger(window=3600.0)
    for jid, ph, t0, t1, chips, pg, seg in rows:
        a.record(Interval(jid, ph, t0, t1, chips, seg), pg=pg)
    b = GoodputLedger(window=3600.0)
    b.add_intervals(*_columns(rows))
    assert b.totals() == a.totals()
    assert b.n_events == a.n_events == 64


def test_add_intervals_skips_zero_chip_time_rows_like_record():
    led = GoodputLedger()
    n = led.add_intervals(["a", "b"], [Phase.STEP, Phase.STEP],
                          [0.0, 5.0], [0.0, 5.0], [4, 4], [0.5, 0.5],
                          [{}, {}])
    assert n == 0 and led.n_events == 0


def test_batch_subscriber_sees_columnar_flushes():
    led = GoodputLedger()
    batches, singles = [], []
    led.subscribe_events(lambda iv, pg: singles.append(iv),
                         batch_fn=batches.append)
    rows = _rows(32)
    led.add_intervals(*_columns(rows))
    assert singles == []        # batch-aware: no per-event dispatch
    assert len(batches) >= 1
    assert all(isinstance(b, IntervalBatch) for b in batches)
    assert sum(len(b.job_ids) for b in batches) == 32
    # chip_times are the precomputed (t1-t0)*chips, bit-for-bit
    b0 = batches[0]
    assert b0.chip_times[0] == (b0.t1[0] - b0.t0[0]) * b0.chips[0]
    # a per-event record still reaches the batch-aware subscriber
    led.record(Interval("x", Phase.STEP, 0.0, 2.0, 8, {}), pg=0.5)
    assert sum(len(b.job_ids) for b in batches) \
        + len(singles) == 33


def test_legacy_subscriber_still_sees_every_event_from_batches():
    led = GoodputLedger()
    seen = []
    led.subscribe_events(lambda iv, pg: seen.append((iv, pg)))
    rows = _rows(24)
    led.add_intervals(*_columns(rows))
    assert len(seen) == 24      # batch path materializes Intervals for it
    assert [iv.job_id for iv, _ in seen] == [r[0] for r in rows]
    assert [pg for _, pg in seen] == [r[5] for r in rows]
