"""Per-architecture smoke tests: reduced same-family configs, one forward /
train step on CPU, asserting output shapes and no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import model
from repro.models.config import ModelConfig, ShapeConfig, SHAPES, shape_applicable


SMOKE_SHAPE = ShapeConfig("smoke", "train", 32, 2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke(arch)
    params = model.init_params(cfg, jax.random.key(0))
    batch = model.synthetic_batch(cfg, SMOKE_SHAPE, jax.random.key(1))

    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn(cfg), has_aux=True)(p, b)
        return loss, metrics, grads

    loss, metrics, grads = jax.jit(step)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gnorm = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        grads, jnp.zeros(()))
    assert jnp.isfinite(gnorm), f"{arch}: non-finite grads"
    assert float(gnorm) > 0, f"{arch}: zero grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke(arch)
    params = model.init_params(cfg, jax.random.key(0))
    batch = model.synthetic_batch(cfg, SMOKE_SHAPE, jax.random.key(1))
    logits, cache = jax.jit(model.prefill_fn(cfg))(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = jax.jit(model.decode_fn(cfg))(params, tok, cache)
    assert logits2.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits2).all()
    # pos is a (b,) per-slot vector for decoder families, scalar for encdec
    assert jnp.all(jnp.asarray(cache2["pos"]) == jnp.asarray(cache["pos"]) + 1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact(arch):
    """The full config matches the published numbers (no allocation)."""
    cfg = get_config(arch)
    published = {
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "rwkv6-3b": (32, 2560, 1, 1, 8960, 65536),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == published, f"{arch}: {got} != {published}"


def test_long_500k_applicability():
    """Sub-quadratic archs (and only those) run the long_500k cell."""
    runs = {a for a in ARCH_IDS
            if shape_applicable(get_config(a),
                                [s for s in SHAPES if s.name == "long_500k"][0])[0]}
    assert runs == {"mixtral-8x7b", "recurrentgemma-2b", "rwkv6-3b"}


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "deepseek-moe-16b"])
def test_moe_active_params_fraction(arch):
    cfg = get_config(arch)
    assert cfg.num_active_params() < 0.5 * cfg.num_params()
