"""Graceful degradation when ``hypothesis`` is not installed.

Property-based tests decorate with ``@settings(...)`` / ``@given(...)``
and build strategies from ``st`` at *module import* time, so a plain
``pytest.importorskip`` would skip whole modules (and their many
non-property tests) or die at collection.  These stand-ins let the
module import cleanly: strategy expressions evaluate to inert
placeholders and ``@given`` replaces the test with a zero-argument
skip, leaving every example-based test in the module runnable.

Install the real thing with the ``test`` extra: ``pip install -e .[test]``.
"""
from __future__ import annotations

import pytest


def settings(*_args, **_kwargs):
    """No-op replacement for ``hypothesis.settings`` as a decorator."""
    def deco(fn):
        return fn
    return deco


def given(*_args, **_kwargs):
    """Replace the property test with a zero-arg skip (keeping its name,
    so -k selections and reports stay stable)."""
    def deco(fn):
        @pytest.mark.skip(reason="hypothesis not installed "
                                 "(pip install -e .[test])")
        def _skipped():
            pass          # pragma: no cover
        _skipped.__name__ = fn.__name__
        _skipped.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        _skipped.__doc__ = fn.__doc__
        return _skipped
    return deco


class _Strategy:
    """Inert placeholder: any strategy-combinator expression evaluates to
    another placeholder instead of raising at module import."""

    def __call__(self, *args, **kwargs):
        return _Strategy()

    def __getattr__(self, name):
        return _Strategy()


st = _Strategy()
