"""Runtime layer tests: checkpoint protocol, async writes, pipeline
bottleneck analysis, orchestrator preempt/resume."""
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataPipeline
from repro.runtime.checkpoint import CheckpointManager


def _state(x=0.0):
    return {"w": jnp.full((4, 4), x), "step": jnp.asarray(int(x))}


def test_checkpoint_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(_state(3.0), step=3)
    restored, step = m.restore(_state())
    assert step == 3
    np.testing.assert_array_equal(restored["w"], np.full((4, 4), 3.0))


def test_checkpoint_gc_keeps_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        m.save(_state(float(s)), step=s)
    assert m.committed_steps() == [3, 4]


def test_checkpoint_torn_write_invisible(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(_state(1.0), step=1)
    # simulate a torn write: directory without manifest
    bad = tmp_path / "step_0000000009"
    bad.mkdir()
    (bad / "arr_00000.npy").write_bytes(b"garbage")
    restored, step = m.restore(_state())
    assert step == 1  # torn step 9 ignored


def test_async_checkpoint_commits(tmp_path):
    m = CheckpointManager(str(tmp_path), async_mode=True)
    m.save(_state(7.0), step=7)
    m.wait()
    restored, step = m.restore(_state())
    assert step == 7
    np.testing.assert_array_equal(restored["w"], np.full((4, 4), 7.0))
    assert m.metrics["device_pause_s"] < m.metrics["write_s"] + 1.0


def test_pipeline_prefetch_and_plumber():
    p = DataPipeline(100, batch=2, seq=16, prefetch=2,
                     extra_stage_cost_s=0.002).start()
    for _ in range(10):
        b = next(p)
        assert b["tokens"].shape == (2, 16)
    p.stop()
    stats = p.analyze()
    stage, frac = stats.bottleneck()
    assert stage == "augment"        # the expensive stage is found
    assert frac > 0.5


def test_orchestrator_resume(tmp_path):
    from repro.configs import get_smoke
    from repro.runtime.orchestrator import Orchestrator, RunConfig

    cfg = get_smoke("smollm-135m")
    r1 = Orchestrator(cfg, RunConfig(steps=12, checkpoint_every=4, batch=2,
                                     seq=32, ckpt_dir=str(tmp_path),
                                     preempt_at_step=9))
    out1 = r1.run()
    assert out1["preempted"]
    r2 = Orchestrator(cfg, RunConfig(steps=12, checkpoint_every=4, batch=2,
                                     seq=32, ckpt_dir=str(tmp_path)))
    out2 = r2.run()
    assert out2["start_step"] == 8       # last commit at step 7
    assert not out2["preempted"]
    assert out2["end_step"] == 12
