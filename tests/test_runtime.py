"""Runtime layer tests: checkpoint protocol, async writes, fault-injected
recovery, pipeline bottleneck analysis, orchestrator preempt/resume."""
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataPipeline
from repro.runtime.checkpoint import (CheckpointManager, FaultInjector,
                                      SimulatedCrash)


def _state(x=0.0):
    return {"w": jnp.full((4, 4), x), "step": jnp.asarray(int(x))}


def test_checkpoint_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(_state(3.0), step=3)
    restored, step = m.restore(_state())
    assert step == 3
    np.testing.assert_array_equal(restored["w"], np.full((4, 4), 3.0))


def test_checkpoint_gc_keeps_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        m.save(_state(float(s)), step=s)
    assert m.committed_steps() == [3, 4]


def test_checkpoint_torn_write_invisible(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(_state(1.0), step=1)
    # simulate a torn write: directory without manifest
    bad = tmp_path / "step_0000000009"
    bad.mkdir()
    (bad / "arr_00000.npy").write_bytes(b"garbage")
    restored, step = m.restore(_state())
    assert step == 1  # torn step 9 ignored


def test_crash_between_arrays_and_manifest_commit(tmp_path):
    """A kill after the array writes but before the manifest commit must
    leave the previous committed step as the restore target."""
    m = CheckpointManager(str(tmp_path),
                          fault_injector=FaultInjector("after_arrays",
                                                       skip=1))
    m.save(_state(1.0), step=1)              # first write survives (skip=1)
    with pytest.raises(SimulatedCrash):
        m.save(_state(2.0), step=2)          # dies before manifest commit
    restored, step = CheckpointManager(str(tmp_path)).restore(_state())
    assert step == 1
    np.testing.assert_array_equal(restored["w"], np.full((4, 4), 1.0))


def test_crash_before_rename_commit(tmp_path):
    """A kill after the manifest lands in the tmp dir but before the
    rename: the tmp dir is not a committed step, restore falls back."""
    m = CheckpointManager(str(tmp_path),
                          fault_injector=FaultInjector("before_commit",
                                                       skip=1))
    m.save(_state(3.0), step=3)
    with pytest.raises(SimulatedCrash):
        m.save(_state(4.0), step=4)
    restored, step = CheckpointManager(str(tmp_path)).restore(_state())
    assert step == 3


def test_corrupted_manifest_is_skipped_not_raised(tmp_path):
    """A truncated/garbage manifest behind a committed-looking directory
    falls back to the previous committed step."""
    m = CheckpointManager(str(tmp_path))
    m.save(_state(1.0), step=1)
    m.save(_state(2.0), step=2)
    (tmp_path / "step_0000000002" / "manifest.json").write_text('{"step": 2')
    restored, step = m.restore(_state())
    assert step == 1
    np.testing.assert_array_equal(restored["w"], np.full((4, 4), 1.0))


def test_truncated_array_is_skipped_not_raised(tmp_path):
    """A torn array file behind a valid manifest is equally invisible."""
    m = CheckpointManager(str(tmp_path))
    m.save(_state(1.0), step=1)
    m.save(_state(2.0), step=2)
    (tmp_path / "step_0000000002" / "arr_00000.npy").write_bytes(b"torn")
    restored, step = m.restore(_state())
    assert step == 1


def test_kill_mid_restore_then_clean_retry(tmp_path):
    """A crash mid-restore corrupts nothing: a fresh restore of the same
    directory succeeds at the same committed step."""
    m = CheckpointManager(str(tmp_path))
    m.save(_state(5.0), step=5)
    dying = CheckpointManager(str(tmp_path),
                              fault_injector=FaultInjector("mid_restore"))
    with pytest.raises(SimulatedCrash):
        dying.restore(_state())
    restored, step = CheckpointManager(str(tmp_path)).restore(_state())
    assert step == 5
    np.testing.assert_array_equal(restored["w"], np.full((4, 4), 5.0))


def test_streaming_restore_matches_blocking_restore(tmp_path):
    """start_restore/finish_restore returns the same state as restore()
    plus the overlap accounting triple."""
    m = CheckpointManager(str(tmp_path))
    m.save(_state(9.0), step=9)
    fut = m.start_restore()
    restored, step, stats = m.finish_restore(fut, _state())
    assert step == 9
    np.testing.assert_array_equal(restored["w"], np.full((4, 4), 9.0))
    assert set(stats) == {"read_s", "exposed_s", "overlap_s"}
    assert stats["read_s"] >= 0 and stats["overlap_s"] >= 0
    assert stats["overlap_s"] == pytest.approx(
        max(0.0, stats["read_s"] - stats["exposed_s"]))


def test_streaming_restore_empty_dir(tmp_path):
    m = CheckpointManager(str(tmp_path))
    state, step, stats = m.finish_restore(m.start_restore(), _state())
    assert state is None and step == -1


def test_async_checkpoint_commits(tmp_path):
    m = CheckpointManager(str(tmp_path), async_mode=True)
    m.save(_state(7.0), step=7)
    m.wait()
    restored, step = m.restore(_state())
    assert step == 7
    np.testing.assert_array_equal(restored["w"], np.full((4, 4), 7.0))
    assert m.metrics["device_pause_s"] < m.metrics["write_s"] + 1.0


def test_pipeline_prefetch_and_plumber():
    p = DataPipeline(100, batch=2, seq=16, prefetch=2,
                     extra_stage_cost_s=0.002).start()
    for _ in range(10):
        b = next(p)
        assert b["tokens"].shape == (2, 16)
    p.stop()
    stats = p.analyze()
    stage, frac = stats.bottleneck()
    assert stage == "augment"        # the expensive stage is found
    assert frac > 0.5


def test_orchestrator_resume(tmp_path):
    from repro.configs import get_smoke
    from repro.runtime.orchestrator import Orchestrator, RunConfig

    cfg = get_smoke("smollm-135m")
    r1 = Orchestrator(cfg, RunConfig(steps=12, checkpoint_every=4, batch=2,
                                     seq=32, ckpt_dir=str(tmp_path),
                                     preempt_at_step=9))
    out1 = r1.run()
    assert out1["preempted"]
    r2 = Orchestrator(cfg, RunConfig(steps=12, checkpoint_every=4, batch=2,
                                     seq=32, ckpt_dir=str(tmp_path)))
    out2 = r2.run()
    assert out2["start_step"] == 8       # last commit at step 7
    assert not out2["preempted"]
    assert out2["end_step"] == 12


def _lost_chip_time_by_layer(ledger):
    from repro.core.goodput import Phase

    by_layer = ledger.segment_phase_chip_time("layer")
    return {layer: phases.get(Phase.LOST.value, 0.0)
            for layer, phases in by_layer.items()}


@pytest.mark.parametrize("kind,layer", [("preemption", "scheduling"),
                                        ("hardware", "hardware")])
def test_failure_kind_moves_the_lost_waterfall_cell(tmp_path, kind, layer):
    """The rollback after a kill lands in the layer matching its cause:
    scheduling for preemptions, hardware for chip failures — the
    waterfall-cell regression for the failure-kind attribution."""
    from repro.configs import get_smoke
    from repro.runtime.orchestrator import Orchestrator, RunConfig

    cfg = get_smoke("smollm-135m")
    orc = Orchestrator(cfg, RunConfig(steps=12, checkpoint_every=4, batch=2,
                                      seq=32, ckpt_dir=str(tmp_path),
                                      preempt_at_step=9,
                                      failure_kind=kind))
    out = orc.run()
    assert out["preempted"]
    lost = _lost_chip_time_by_layer(orc.ledger)
    other = "hardware" if layer == "scheduling" else "scheduling"
    assert lost.get(layer, 0.0) > 0.0
    assert lost.get(other, 0.0) == 0.0


def test_failure_kind_validated():
    from repro.runtime.orchestrator import RunConfig

    with pytest.raises(ValueError, match="failure_kind"):
        RunConfig(failure_kind="cosmic_ray")


def test_async_restore_overlap_in_summary(tmp_path):
    """Resuming with async_restore reports the overlap accounting and
    restores the same step the blocking path would."""
    from repro.configs import get_smoke
    from repro.runtime.orchestrator import Orchestrator, RunConfig

    cfg = get_smoke("smollm-135m")

    def preempted_dir(name):
        d = str(tmp_path / name)
        base = dict(steps=12, checkpoint_every=4, batch=2, seq=32,
                    ckpt_dir=d)
        Orchestrator(cfg, RunConfig(preempt_at_step=9, **base)).run()
        return base

    out = Orchestrator(cfg, RunConfig(async_restore=True,
                                      **preempted_dir("a"))).run()
    assert out["start_step"] == 8
    assert set(out["restore"]) == {"read_s", "exposed_s", "overlap_s"}
    assert out["restore"]["read_s"] > 0.0
    # the read started before compile/param-init, so some (typically all)
    # of it is hidden behind setup — the measured INIT reduction
    assert out["restore"]["overlap_s"] == pytest.approx(
        max(0.0, out["restore"]["read_s"] - out["restore"]["exposed_s"]))
    out2 = Orchestrator(cfg, RunConfig(async_restore=False,
                                       **preempted_dir("b"))).run()
    assert out2["start_step"] == 8
    assert out2["restore"]["overlap_s"] == 0.0


def _compiler_init_chip_time(ledger):
    from repro.core.goodput import Layer, Phase

    by_layer = ledger.segment_phase_chip_time("layer")
    return by_layer.get(Layer.COMPILER.value, {}).get(Phase.INIT.value, 0.0)


def test_compile_clock_feeds_compiler_layer_init(tmp_path):
    """The CompileClock regression: a cold AOT cache books its compile
    wall-time as compiler-layer INIT chip-time; a warm cache books none,
    so the PG/RG attribution visibly moves between runs."""
    from repro.configs import get_smoke
    from repro.runtime.compile_cache import AotCache
    from repro.runtime.orchestrator import Orchestrator, RunConfig

    cfg = get_smoke("smollm-135m")
    aot = AotCache()                    # shared across both runs
    cold = Orchestrator(cfg, RunConfig(steps=3, checkpoint_every=2, batch=2,
                                       seq=32, ckpt_dir=str(tmp_path / "a")),
                        aot=aot)
    out_cold = cold.run()
    assert out_cold["compile_s"] > 0
    cold_compile = _compiler_init_chip_time(cold.ledger)
    assert cold_compile > 0.0

    warm = Orchestrator(cfg, RunConfig(steps=3, checkpoint_every=2, batch=2,
                                       seq=32, ckpt_dir=str(tmp_path / "b")),
                        aot=aot)
    warm.run()
    assert _compiler_init_chip_time(warm.ledger) == 0.0
    # the warm run still pays framework-layer setup (restore, pipeline)
    from repro.core.goodput import Layer, Phase
    warm_fw = warm.ledger.segment_phase_chip_time("layer")
    assert warm_fw[Layer.FRAMEWORK.value][Phase.INIT.value] > 0.0


def test_orchestrator_emits_measured_data_stall(tmp_path):
    """DATA_STALL comes from measured PipelineStats (consumer wait +
    bottleneck stage), not a per-batch wall-clock heuristic."""
    from repro.core.goodput import Layer, Phase
    from repro.configs import get_smoke
    from repro.runtime.orchestrator import Orchestrator, RunConfig

    cfg = get_smoke("smollm-135m")
    orc = Orchestrator(cfg, RunConfig(steps=4, checkpoint_every=10, batch=2,
                                      seq=32, ckpt_dir=str(tmp_path)))
    out = orc.run()
    assert set(out["data"]) == {"bottleneck_stage", "bottleneck_share",
                                "input_bound", "consumer_wait_s"}
    stall = orc.ledger.phase_chip_time(Phase.DATA_STALL)
    assert stall == pytest.approx(out["data"]["consumer_wait_s"]
                                  * orc.run_cfg.chips)
    if stall > 0:
        by_layer = orc.ledger.segment_phase_chip_time("layer")
        assert by_layer[Layer.DATA.value][Phase.DATA_STALL.value] == \
            pytest.approx(stall)


def test_orchestrator_keep_intervals_opt_out(tmp_path):
    """Attribution-scale runs opt out of interval retention and stay
    O(1) memory while the streaming reports keep working."""
    from repro.configs import get_smoke
    from repro.core.attribution import AttributionWaterfall
    from repro.runtime.orchestrator import Orchestrator, RunConfig

    cfg = get_smoke("smollm-135m")
    orc = Orchestrator(cfg, RunConfig(steps=3, checkpoint_every=2, batch=2,
                                      seq=32, ckpt_dir=str(tmp_path)),
                       keep_intervals=False)
    wf = AttributionWaterfall().attach(orc.ledger)
    orc.run()
    assert orc.ledger.intervals is None
    with pytest.raises(AttributeError):
        orc.intervals
    wf.assert_conserves(orc.ledger)
    assert sum(wf.state_size().values()) < 50
