"""Scenario subsystem tests: preset registry, composable modifiers, and
the physical effect of each fleet condition (maintenance drains, failure
bursts, arrival modulation, heterogeneous generations)."""
import dataclasses
import math

import pytest

from repro.core.goodput import generation_pg_weights
from repro.fleet.scenarios import (GOLDEN_KNOBS, SCENARIOS, ArrivalModulation,
                                   FailureBurst, MaintenanceWindow, Scenario,
                                   build_sim, golden_sim)
from repro.fleet.sim import MAINT_TAG
from repro.fleet.workload import generate_jobs, warp_times


def _quick(scenario, seed=0, **kw):
    knobs = dict(n_jobs=60, seed=seed, n_pods=4, pod_size=64,
                 horizon=2 * 24 * 3600.0, retain_intervals=False)
    knobs.update(kw)
    sim = build_sim(scenario, **knobs)
    sim.run()
    return sim


# ---------------------------------------------------------------------------
# registry + modifiers
# ---------------------------------------------------------------------------

def test_preset_registry_has_at_least_six_named_scenarios():
    assert len(SCENARIOS) >= 6
    for name, scn in SCENARIOS.items():
        assert scn.name == name
        assert scn.description


def test_modifiers_compose_and_do_not_mutate():
    base = SCENARIOS["steady"]
    combo = base.diurnal(amplitude=0.5).failure_storm(bursts=2).hetero()
    assert base.arrival.kind == "uniform" and not base.bursts
    assert combo.arrival.kind == "diurnal"
    assert len(combo.bursts) == 2
    assert combo.pod_generations
    assert combo.mtbf_factor < 1.0
    # frozen: in-place mutation is an error
    with pytest.raises(dataclasses.FrozenInstanceError):
        combo.name = "x"


def test_unknown_preset_and_generation_rejected():
    with pytest.raises(ValueError, match="unknown scenario preset"):
        golden_sim("bogus")
    with pytest.raises(ValueError, match="generation"):
        _quick(Scenario("bad").hetero(generations=("tpu-v99",)))


def test_generation_pg_weights_normalize_to_best():
    w = generation_pg_weights(["tpu-v4", "tpu-v5e", "tpu-v5p"])
    assert max(w.values()) == 1.0
    assert all(0.0 < v <= 1.0 for v in w.values())
    assert w["tpu-v5p"] == 1.0            # best peak present
    assert w["tpu-v5e"] < w["tpu-v4"]     # v4 peaks higher than v5e


# ---------------------------------------------------------------------------
# arrival modulation
# ---------------------------------------------------------------------------

def test_warp_preserves_span_and_monotonicity():
    mod = ArrivalModulation(kind="diurnal", amplitude=0.8, period=86400.0)
    span = 0.8 * 2 * 86400.0
    us = [i * span / 50 for i in range(51)]
    ts = [warp_times(u, mod.intensity, span) for u in us]
    assert all(0.0 <= t <= span for t in ts)
    assert ts == sorted(ts)               # inverse CDF is monotone
    assert warp_times(0.0, mod.intensity, span) == pytest.approx(0.0, abs=1.0)


def test_diurnal_concentrates_arrivals_at_peak():
    horizon = 2 * 86400.0
    base = generate_jobs(200, horizon, seed=1, pg_table={})
    mod = ArrivalModulation(kind="diurnal", amplitude=0.9)
    warped = generate_jobs(200, horizon, seed=1, pg_table={},
                           arrival_profile=mod.intensity)
    # everything except arrival is byte-identical to the base workload
    for a, b in zip(base, warped):
        assert dataclasses.replace(a, arrival=0.0) == \
            dataclasses.replace(b, arrival=0.0)
    # peak half-day (intensity > 1) holds more arrivals than trough half
    def day_phase(t):
        return math.sin(2 * math.pi * t / 86400.0 - math.pi / 2)
    peak = sum(1 for j in warped if day_phase(j.arrival) > 0)
    trough = len(warped) - peak
    assert peak > trough * 1.5


def test_bursty_modulation_clusters_arrivals():
    horizon = 86400.0
    mod = ArrivalModulation(kind="bursty", burst_gain=9.0,
                            burst_every=6 * 3600.0, burst_width=1800.0)
    jobs = generate_jobs(300, horizon, seed=2, pg_table={},
                         arrival_profile=mod.intensity)
    in_burst = sum(1 for j in jobs
                   if (j.arrival % (6 * 3600.0)) < 1800.0)
    # burst windows are ~8% of the span but attract ~45% of arrivals
    assert in_burst / len(jobs) > 0.25


# ---------------------------------------------------------------------------
# maintenance windows
# ---------------------------------------------------------------------------

def test_maintenance_reserves_and_returns_the_pod():
    scn = Scenario("m", "one window", maintenance=(
        MaintenanceWindow(pod=0, start_frac=0.4, end_frac=0.6),))
    sim = _quick(scn)
    # window over: sentinel released, nothing leaks
    assert not any(tag.startswith(MAINT_TAG)
                   for tag in sim.cluster.allocations)
    assert sim.cluster.free_chips() == sim.cluster.total_chips


def test_overlapping_maintenance_windows_take_union_semantics():
    """Two overlapping windows on one pod keep it reserved until the
    *last* end (depth-counted), and release it exactly once."""
    scn = Scenario("ov", "overlap", maintenance=(
        MaintenanceWindow(pod=0, start_frac=0.3, end_frac=0.6),
        MaintenanceWindow(pod=0, start_frac=0.5, end_frac=0.9),))
    sim = build_sim(scn, n_jobs=30, seed=7, n_pods=2, pod_size=64,
                    horizon=24 * 3600.0, retain_intervals=False)
    reserved_at = {}
    real_run = sim.run

    # sample reservation state at each event by wrapping _try_schedule
    orig = sim._try_schedule

    def probe():
        reserved_at[sim.now] = any(t.startswith(MAINT_TAG)
                                   for t in sim.cluster.allocations)
        orig()

    sim._try_schedule = probe
    real_run()
    h = sim.cfg.horizon
    # between the first end (0.6h) and the second end (0.9h) the pod must
    # still be reserved; after 0.9h it must be free
    mid = [r for t, r in reserved_at.items() if 0.62 * h < t < 0.88 * h]
    late = [r for t, r in reserved_at.items() if t > 0.92 * h]
    assert mid and all(mid)
    assert not any(late)
    assert sim.cluster.free_chips() == sim.cluster.total_chips


def test_maintenance_costs_sg_on_a_busy_fleet():
    """On a *saturated* fleet (demand > capacity, every job schedulable)
    a drained pod is allocated chip-time lost for good, so SG drops.  On
    an underloaded fleet the work just relocates — which is why this is
    asserted here under saturation and only *recorded* by the sweep."""
    mix = {"small": 0.5, "medium": 0.5}   # every size fits a 64-chip pod
    steady = _quick(SCENARIOS["steady"].load(1.5), seed=3, size_mix=mix)
    maint = _quick(SCENARIOS["steady"].load(1.5).maintenance_wave(
        pods=2, start_frac=0.3, width_frac=0.25).named("maintenance"),
        seed=3, size_mix=mix)
    assert maint.report().sg < steady.report().sg


# ---------------------------------------------------------------------------
# failure bursts / MTBF shocks
# ---------------------------------------------------------------------------

def test_failure_storm_causes_more_failures_and_lost_work():
    steady = _quick(SCENARIOS["steady"], seed=4)
    storm = _quick(SCENARIOS["failure_storm"], seed=4)
    f_steady = sum(j.failures for j in steady.jobs.values())
    f_storm = sum(j.failures for j in storm.jobs.values())
    assert f_storm > f_steady
    from repro.core.goodput import Phase

    assert storm.ledger.phase_chip_time(Phase.LOST) >= \
        steady.ledger.phase_chip_time(Phase.LOST)


def test_burst_kill_frac_one_fails_every_running_job():
    scn = Scenario("k", "total burst",
                   bursts=(FailureBurst(at_frac=0.5, kill_frac=1.1),))
    sim = _quick(scn, seed=5)
    assert sum(j.failures for j in sim.jobs.values()) >= 1


def test_kill_during_setup_clips_init_no_phantom_chip_time():
    """A burst landing while a job is still in INIT must truncate the
    setup interval at the kill time — no phantom allocated chip-time
    bleeding past the kill into the restarted segment's window."""
    from repro.core.goodput import Phase
    from repro.fleet.job import JobSpec
    from repro.fleet.sim import FleetSim, SimConfig

    horizon = 6 * 3600.0
    burst_t = 1800.0
    scn = Scenario("clip", "mid-init burst", bursts=(
        FailureBurst(at_frac=burst_t / horizon, kill_frac=1.1),))
    cfg = SimConfig(n_pods=1, pod_size=8, horizon=horizon,
                    chip_mtbf=1e15, seed=0, scenario=scn)
    sim = FleetSim(cfg)
    sim.submit(JobSpec(job_id="j", chips=8, work=8 * 7200.0,
                       init_time=3600.0, arrival=0.0,
                       data_stall_frac=0.0))
    sim.run()
    inits = [iv for iv in sim.intervals if iv.phase == Phase.INIT]
    # epoch 1's INIT is clipped at the burst, epoch 2's starts there
    assert [(iv.t0, iv.t1) for iv in inits[:2]] == \
        [(0.0, burst_t), (burst_t, burst_t + 3600.0)]
    # nothing allocated overlaps the kill boundary
    for iv in sim.intervals:
        assert not (iv.t0 < burst_t < iv.t1)


# ---------------------------------------------------------------------------
# heterogeneous generations
# ---------------------------------------------------------------------------

def test_hetero_fleet_lowers_pg_and_tags_generation():
    steady = _quick(SCENARIOS["steady"], seed=6)
    hetero = _quick(SCENARIOS["hetero_fleet"], seed=6)
    assert hetero.report().pg < steady.report().pg
    by_gen = hetero.ledger.segment_phase_chip_time("generation")
    assert len(by_gen) >= 2               # several generations saw work
    assert hetero.pod_factor and max(hetero.pod_factor) == 1.0
    assert min(hetero.pod_factor) < 1.0


# ---------------------------------------------------------------------------
# every preset stays physical (example-based mirror of the property test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", sorted(SCENARIOS))
def test_every_preset_keeps_goodput_terms_in_unit_range(preset):
    sim = build_sim(SCENARIOS[preset], **GOLDEN_KNOBS)
    sim.run()
    rep = sim.report()
    for v in (rep.sg, rep.rg, rep.pg, rep.mpg):
        assert 0.0 <= v <= 1.0
    # chip-time conservation: allocated time never exceeds capacity
    alloc = rep.allocated_chip_time
    assert alloc <= sim.capacity_chip_time * 1.001
    assert rep.productive_chip_time <= alloc + 1e-9
    assert rep.ideal_chip_time <= rep.productive_chip_time + 1e-9
