"""Serve-layer tests: tail-batch padding correctness (the double-count
bug) and the serve-side goodput emitter (QUEUED/INIT/STEP/IDLE)."""
import time

import numpy as np
import pytest

from repro.core.goodput import Layer, Phase
from repro.core.ledger import GoodputLedger
from repro.launch.serve import Request, Server, TickClock, pad_group


def test_pad_group_uses_sentinel_clones():
    reqs = [Request(i, np.zeros(4, np.int32), 8) for i in range(2)]
    padded = pad_group(reqs, 4)
    assert len(padded) == 4
    assert [r.rid for r in padded[:2]] == [0, 1]
    assert all(r.is_pad for r in padded[2:])
    # clones must not share mutable state with the real requests
    padded[2].out_tokens.append(123)
    assert reqs[0].out_tokens == []


def test_pad_group_fills_tiny_tail_to_full_width():
    """A tail smaller than half the batch still pads to full width (the
    clone source cycles), keeping the compiled batch shape stable."""
    reqs = [Request(0, np.zeros(4, np.int32), 8)]
    padded = pad_group(reqs, 8)
    assert len(padded) == 8
    assert sum(r.is_pad for r in padded) == 7


def test_pad_group_full_batch_unchanged():
    reqs = [Request(i, np.zeros(4, np.int32), 8) for i in range(4)]
    assert pad_group(reqs, 4) == reqs


def test_pad_group_empty_group_raises():
    """Regression: an empty group used to hit modulo-by-zero in the
    clone-source cycle; now it is rejected up front."""
    with pytest.raises(ValueError, match="empty"):
        pad_group([], 4)


def test_server_rejects_nonpositive_batch():
    from repro.configs import get_smoke

    with pytest.raises(ValueError, match="batch"):
        Server(get_smoke("smollm-135m"), batch=0, max_len=12)


@pytest.fixture(scope="module")
def smoke_server():
    from repro.configs import get_smoke

    cfg = get_smoke("smollm-135m")
    ledger = GoodputLedger(window=60.0)
    server = Server(cfg, batch=4, max_len=12, ledger=ledger)
    return cfg, server, ledger


def _requests(cfg, n, prompt_len=8, max_new=4):
    rng = np.random.default_rng(0)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    prompt_len).astype(np.int32),
                    max_new, t_submit=time.monotonic())
            for i in range(n)]


def test_padded_tail_batch_not_double_counted(smoke_server):
    """6 requests at batch 4: the tail batch carries 2 sentinel pads.
    Before the fix the duplicated Request objects got tokens appended
    twice and t_first/t_done overwritten, inflating throughput."""
    cfg, server, _ = smoke_server
    reqs = _requests(cfg, 6)
    for i in range(0, len(reqs), 4):
        server.run_batch(pad_group(reqs[i:i + 4], 4))
    assert all(len(r.out_tokens) == r.max_new for r in reqs)
    assert sum(len(r.out_tokens) for r in reqs) == 6 * 4
    assert all(r.t_done >= r.t_first > 0 for r in reqs)


def test_serve_emits_all_accounting_phases(smoke_server):
    cfg, server, ledger = smoke_server
    before = ledger.n_events
    reqs = _requests(cfg, 3)          # batch of 4 -> one pad slot
    server.run_batch(pad_group(reqs, 4))
    assert ledger.n_events > before
    for phase in (Phase.QUEUED, Phase.INIT, Phase.STEP, Phase.IDLE):
        assert ledger.phase_chip_time(phase) > 0.0, phase
    bd = ledger.rg_breakdown()
    assert "step" in bd and "idle" in bd
    assert sum(bd.values()) == pytest.approx(1.0)
    # serve segment tagging feeds the fleet-wide phase_kind split (Fig. 15)
    by = ledger.segment_report("phase_kind", {"serve": 1.0})
    assert "serve" in by
    # cross-layer provenance: serve events carry emitter=serve (trace
    # source) plus a canonical stack-layer tag for attribution
    assert "serve" in ledger.segment_report("emitter", {"serve": 1.0})
    layers = set(ledger.segment_report("layer", {}))
    assert layers <= {l.value for l in Layer}
    assert {"model", "scheduling"} <= layers


def test_injected_tick_clock_makes_serve_accounting_deterministic():
    """The determinism-audit fix for wall-clock reads: with a virtual
    clock the serve emitter's interval stream — and hence the ledger
    totals a recorded serve trace must reproduce — is identical across
    runs."""
    from repro.configs import get_smoke

    cfg = get_smoke("smollm-135m")

    def run_once():
        clock = TickClock(dt=0.25)
        ledger = GoodputLedger(window=60.0)
        server = Server(cfg, batch=2, max_len=12,
                        ledger=ledger, clock=clock)
        reqs = [Request(i, np.full(8, i + 1, np.int32), 3,
                        t_submit=clock()) for i in range(3)]
        for i in range(0, len(reqs), 2):
            server.run_batch(pad_group(reqs[i:i + 2], 2))
        return ledger.totals()

    first, second = run_once(), run_once()
    assert first == second          # exact: every float bit-identical
    assert first["n_events"] > 0


class CountingClock(TickClock):
    """TickClock that also counts how many times it was read."""

    def __init__(self, dt=0.25):
        super().__init__(dt=dt)
        self.reads = 0

    def __call__(self):
        self.reads += 1
        return super().__call__()


def _tick_server(batch=2, dt=0.25):
    from repro.configs import get_smoke

    cfg = get_smoke("smollm-135m")
    clock = CountingClock(dt=dt)
    ledger = GoodputLedger(window=60.0)
    server = Server(cfg, batch=batch, max_len=12,
                    ledger=ledger, clock=clock)
    reqs = [Request(i, np.full(8, i + 1, np.int32), 3, t_submit=0.0)
            for i in range(batch)]
    return server, ledger, clock, reqs


def test_run_batch_reads_clock_exactly_three_times():
    """Regression (serve-clock skew): per-request clock reads used to
    advance an injected TickClock mid-batch, so t_first/t_done drifted
    past the emitted interval bounds.  A batch has exactly three time
    boundaries — start, prefill end, decode end — and must read the
    clock exactly once at each."""
    server, _, clock, reqs = _tick_server()
    server.run_batch(reqs)
    assert clock.reads == 3
    before = clock.reads
    server.run_batch(reqs)
    assert clock.reads - before == 3


def test_request_timestamps_land_inside_emitted_intervals():
    """t_first/t_done must be consistent with the intervals the emitter
    books: with dt=0.25 the batch spans [t0, t0+0.5], t_first == t0+0.25
    (prefill end) and t_done == t0+0.5 (decode end) for every request —
    not one tick later per slot as under the per-request-read bug."""
    server, ledger, _, reqs = _tick_server(batch=3)
    server.run_batch(reqs)
    t0, t1, t2 = 0.25, 0.5, 0.75
    assert all(r.t_first == t1 for r in reqs)
    assert all(r.t_done == t2 for r in reqs)
    # and the per-slot phase intervals exactly tile batch x [t0, t2]
    span_chip_time = server.batch * (t2 - t0)
    booked = sum(ledger.phase_chip_time(p)
                 for p in (Phase.INIT, Phase.STEP, Phase.IDLE))
    assert booked == pytest.approx(span_chip_time)


def test_run_batch_rejects_wrong_width():
    """Regression: self.batch was stored but never checked, silently
    running whatever width it was handed (breaking capacity math)."""
    server, _, _, reqs = _tick_server(batch=2)
    with pytest.raises(ValueError, match="batch"):
        server.run_batch(reqs[:1])


def test_server_no_longer_accepts_dead_prompt_len():
    """Regression: Server(prompt_len=...) was accepted and ignored."""
    from repro.configs import get_smoke

    with pytest.raises(TypeError):
        Server(get_smoke("smollm-135m"), batch=2, prompt_len=8, max_len=12)


def test_capacity_derived_from_ledger_span():
    """Regression: main() computed capacity as batch * (max t_done -
    min t_submit) — mixing the request wall-clock base with the emitter
    clock base and dividing by zero when they coincided.  Capacity now
    comes from the server's own emitted span."""
    server, ledger, _, reqs = _tick_server(batch=2)
    assert server.capacity_chip_time() == 0.0   # degenerate: nothing run
    server.run_batch(reqs)
    # span is [first t0, last t2] on the injected clock: 0.25 -> 0.75
    assert server.span() == pytest.approx(0.5)
    assert server.capacity_chip_time() == pytest.approx(2 * 0.5)
    rep = ledger.report(capacity_chip_time=server.capacity_chip_time())
    assert 0.0 < rep.sg <= 1.0


def test_degenerate_zero_span_guarded():
    """A zero-dt clock collapses the span; throughput math must return
    0.0 instead of raising ZeroDivisionError."""
    from repro.configs import get_smoke
    from repro.launch.serve import run_static_server

    cfg = get_smoke("smollm-135m")
    reqs = [Request(i, np.full(8, i + 1, np.int32), 2, t_submit=0.0)
            for i in range(2)]
    _, out = run_static_server(cfg, reqs, batch=2, max_new=2, prompt_len=8,
                               clock=TickClock(dt=0.0))
    assert out["throughput_tok_s"] == 0.0
    assert out["capacity_chip_time"] == 0.0
    assert out["tokens_generated"] == 4
