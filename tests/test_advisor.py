"""What-if advisor tests: golden steady-preset ranking (the paper's
Fig 14 qualitative ordering), counterfactual coverage of every scenario
preset, and the trace-rebuild contract (a recorded trace alone rebuilds a
bit-for-bit-identical baseline before any delta is trusted)."""
import dataclasses

import pytest

from repro.fleet.advisor import (KNOBS, Case, _daly_interval, baseline_case,
                                 from_trace, knob_names, run_case, what_if)
from repro.fleet.job import JobSpec
from repro.fleet.scenarios import (GOLDEN_KNOBS, GOLDEN_SIZE_MIX, SCENARIOS)
from repro.fleet.trace import GOLDEN_DIR, Trace

TINY = dict(size_mix=GOLDEN_SIZE_MIX, **GOLDEN_KNOBS)
PRESETS = sorted(SCENARIOS)

# the golden steady-preset ranking at the golden (tiny) scale — pinned
# exactly like a golden trace: the advisor is deterministic, so any
# simulator or knob change that reshuffles it must be a conscious bless.
# Qualitatively this is the paper's Fig 14 story: async checkpointing is
# the headline RG win, ahead of the framework migration; the PG/SG knobs
# are no-ops on a steady homogeneous fleet already running the paper's
# scheduler policies.  The resiliency knobs are steady-state no-ops too
# (multi_slice_gang ties at zero; elastic_resize trades a sliver of
# throughput for restart stability with nothing failing) — their value
# shows up on the failure presets (benchmarks/resilience.py), not here.
GOLDEN_STEADY_RANKING = [
    "async_checkpointing",
    "data_pipeline_2x",
    "single_controller",
    "checkpoint_interval_daly",
    "generation_upgrade",
    "multi_slice_gang",
    "scheduler_paper_policies",
    "elastic_resize",
    "compile_cache_warm",
]


@pytest.fixture(scope="module")
def steady_report():
    return what_if("steady", **TINY)


def test_steady_golden_ranking(steady_report):
    assert [r["knob"] for r in steady_report["ranking"]] == \
        GOLDEN_STEADY_RANKING


def test_steady_ranking_matches_fig14_qualitative_order(steady_report):
    rec = {r["knob"]: r["recovered_mpg"] for r in steady_report["ranking"]}
    assert rec["async_checkpointing"] > rec["compile_cache_warm"]
    assert rec["async_checkpointing"] > rec["single_controller"]
    # no-op knobs must not invent phantom recovery
    assert rec["scheduler_paper_policies"] == 0.0
    assert rec["generation_upgrade"] == 0.0


def test_ranking_rows_are_sorted_and_complete(steady_report):
    rows = steady_report["ranking"]
    assert len(rows) == len(KNOBS)
    recs = [r["recovered_mpg"] for r in rows]
    assert recs == sorted(recs, reverse=True)
    for r in rows:
        assert {"knob", "description", "targets", "SG", "RG", "PG", "MPG",
                "recovered_mpg", "d_sg", "d_rg", "d_pg",
                "recovered_ideal_chip_time"} <= set(r)
        assert r["recovered_ideal_chip_time"] == pytest.approx(
            r["recovered_mpg"]
            * steady_report["baseline"]["capacity_chip_time"])


@pytest.mark.parametrize("preset", PRESETS)
def test_what_if_covers_every_preset(preset):
    rep = what_if(preset, **TINY)
    assert rep["scenario"] == preset
    assert len(rep["ranking"]) == len(KNOBS) >= 5
    assert rep["baseline"]["waterfall"]["conservation"]["conserved"]
    for key in ("SG", "RG", "PG", "MPG"):
        assert 0.0 <= rep["baseline"][key] <= 1.0


def test_generation_upgrade_recovers_pg_on_hetero_fleet():
    rep = what_if("hetero_fleet", knobs=["generation_upgrade"], **TINY)
    row = rep["ranking"][0]
    assert row["d_pg"] > 0.05
    assert row["recovered_mpg"] > 0.0


def test_policy_swap_recovers_on_a_naive_baseline():
    """scheduler_paper_policies is a no-op on paper-policy baselines but
    must recover goodput when the baseline runs the naive combo."""
    rep = what_if("steady", knobs=["scheduler_paper_policies"],
                  placement="spread", preemption="priority_only",
                  defrag="none", **TINY)
    assert rep["ranking"][0]["recovered_mpg"] > 0.0


# ---------------------------------------------------------------------------
# trace-based baselines
# ---------------------------------------------------------------------------

def test_from_trace_rebuilds_and_reproduces_footer():
    trace = Trace.load(GOLDEN_DIR / "steady.jsonl")
    rep = what_if(trace, knobs=["async_checkpointing"])
    assert rep["baseline"]["reproduces_trace"] is True
    assert rep["scenario"] == "steady"
    assert len(rep["ranking"]) == 1


def test_trace_baseline_rejects_overrides():
    trace = Trace.load(GOLDEN_DIR / "steady.jsonl")
    with pytest.raises(ValueError, match="overrides"):
        what_if(trace, knobs=[], n_jobs=50)


def test_from_trace_requires_workload_meta():
    trace = Trace.load(GOLDEN_DIR / "steady.jsonl")
    stripped = dataclasses.replace(
        trace, meta={k: v for k, v in trace.meta.items()
                     if k != "workload"})
    with pytest.raises(ValueError, match="workload"):
        from_trace(stripped)


def test_trace_baseline_is_never_saturated():
    """Trace baselines keep the recorded workload (saturating would break
    the footer-reproduction guard); presets saturate by default."""
    trace = Trace.load(GOLDEN_DIR / "steady.jsonl")
    rep = what_if(trace, knobs=[])
    assert rep["baseline"]["target_load"] == \
        SCENARIOS["steady"].target_load
    preset = what_if("steady", knobs=[], **TINY)
    assert preset["baseline"]["target_load"] > \
        SCENARIOS["steady"].target_load


# ---------------------------------------------------------------------------
# knob mechanics
# ---------------------------------------------------------------------------

def test_daly_interval_formula():
    spec = JobSpec(job_id="j", chips=64, work=1e6, checkpoint_write=30.0)
    base = _daly_interval(spec, mtbf_factor=1.0)
    assert 60.0 <= base <= 86400.0
    # a shakier fleet (lower MTBF) means checkpointing more often
    assert _daly_interval(spec, mtbf_factor=0.25) < base
    # bigger slices fail more often -> shorter interval
    big = dataclasses.replace(spec, chips=1024)
    assert _daly_interval(big, mtbf_factor=1.0) < base


def test_case_mutators_chain():
    case = baseline_case("steady", **TINY)
    case = KNOBS["async_checkpointing"].build(case)
    case = KNOBS["compile_cache_warm"].build(case)
    spec = JobSpec(job_id="j", chips=8, work=1.0)
    mutated = case.job_mutator(spec)
    assert mutated.async_checkpoint and mutated.compile_cache_hit


def test_run_case_self_checks_conservation():
    sim, rep, wf = run_case(baseline_case("steady", **TINY))
    assert wf.totals_match(sim.ledger)
    assert 0.0 <= rep.mpg <= 1.0


def test_knob_names_lists_the_catalog():
    assert knob_names() == sorted(KNOBS)
    assert len(KNOBS) >= 5


# ---------------------------------------------------------------------------
# early-exit: provably-zero knobs are skipped without resimulation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", ("steady", "hetero_fleet",
                                    "failure_storm"))
def test_skip_preserves_ranking_and_recoveries(preset):
    """Skipping unaddressable knobs is an optimization, not a semantic
    change: order and recovered_mpg match the exhaustive sweep exactly
    (a skipped knob was going to score 0.0 anyway)."""
    fast = what_if(preset, **TINY)
    slow = what_if(preset, skip_unaddressable=False, **TINY)
    assert [r["knob"] for r in fast["ranking"]] == \
        [r["knob"] for r in slow["ranking"]]
    for f, s in zip(fast["ranking"], slow["ranking"]):
        assert f["recovered_mpg"] == s["recovered_mpg"]
        if f["skipped"]:
            assert f["recovered_mpg"] == 0.0
    assert not any(r["skipped"] for r in slow["ranking"])


def test_skip_flags_structural_noops_on_steady():
    rep = what_if("steady", **TINY)
    skipped = {r["knob"] for r in rep["ranking"] if r["skipped"]}
    # steady is homogeneous and already runs the paper's scheduler combo
    assert "generation_upgrade" in skipped
    assert "scheduler_paper_policies" in skipped


def test_skip_when_addressed_bucket_is_empty():
    """A workload that never compiles (init_time=0) proves
    compile_cache_warm can recover nothing — the advisor skips it from
    the baseline waterfall instead of resimulating."""
    no_compile = lambda j: dataclasses.replace(j, init_time=0.0)
    rep = what_if("steady", knobs=["compile_cache_warm"],
                  job_mutator=no_compile, **TINY)
    (row,) = rep["ranking"]
    assert row["skipped"] and row["recovered_mpg"] == 0.0
    # and with compile time present it is NOT skipped
    rep = what_if("steady", knobs=["compile_cache_warm"], **TINY)
    (row,) = rep["ranking"]
    assert not row["skipped"]
