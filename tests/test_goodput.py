"""MPG metric unit tests: composition, segmentation, and the paper's
Table 2 direction-of-change matrix."""
import pytest

from repro.core.goodput import (Interval, Phase, compute_goodput,
                                rg_breakdown, segment_goodput)


def iv(job, phase, t0, t1, chips, **seg):
    return Interval(job, phase, t0, t1, chips, seg)


def test_mpg_composition():
    # one job: 10s queued, 10s init, 70s step, 10s checkpoint on 4 chips;
    # fleet capacity = 8 chips for 100s.
    ivs = [
        iv("a", Phase.QUEUED, 0, 10, 4),
        iv("a", Phase.INIT, 10, 20, 4),
        iv("a", Phase.STEP, 20, 90, 4),
        iv("a", Phase.CHECKPOINT, 90, 100, 4),
    ]
    rep = compute_goodput(ivs, capacity_chip_time=8 * 100,
                          pg_by_job={"a": 0.5})
    assert rep.sg == pytest.approx(90 * 4 / 800)    # queued not allocated
    assert rep.rg == pytest.approx(70 / 90)
    assert rep.pg == pytest.approx(0.5)
    assert rep.mpg == pytest.approx(rep.sg * rep.rg * rep.pg)


def test_lost_work_counts_against_rg():
    ivs = [
        iv("a", Phase.STEP, 0, 50, 2),
        iv("a", Phase.LOST, 50, 100, 2),
    ]
    rep = compute_goodput(ivs, capacity_chip_time=200)
    assert rep.rg == pytest.approx(0.5)
    assert rep.sg == pytest.approx(1.0)


def test_segmentation_keeps_denominators():
    """Simpson's paradox guard: segment RGs can both exceed the aggregate
    ordering only when denominators are kept per-segment."""
    ivs = [
        iv("big", Phase.STEP, 0, 90, 100, size_class="xl"),
        iv("big", Phase.IDLE, 90, 100, 100, size_class="xl"),
        iv("sm", Phase.STEP, 0, 10, 1, size_class="small"),
        iv("sm", Phase.IDLE, 10, 100, 1, size_class="small"),
    ]
    by = segment_goodput(ivs, "size_class",
                         {"xl": 10_000, "small": 10_000})
    assert by["xl"].rg == pytest.approx(0.9)
    assert by["small"].rg == pytest.approx(0.1)
    agg = compute_goodput(ivs, 20_000)
    # aggregate is dominated by the xl job — masking the small job's problem
    assert agg.rg > 0.85


def test_rg_breakdown_sums_to_one():
    ivs = [
        iv("a", Phase.STEP, 0, 60, 2),
        iv("a", Phase.CHECKPOINT, 60, 70, 2),
        iv("a", Phase.DATA_STALL, 70, 80, 2),
        iv("a", Phase.INIT, 80, 100, 2),
    ]
    bd = rg_breakdown(ivs)
    assert sum(bd.values()) == pytest.approx(1.0)
    assert bd["step"] == pytest.approx(0.6)


# ---------------------------------------------------------------------------
# Paper Table 2: direction of change per layer optimization
# ---------------------------------------------------------------------------

def _fleet(step, ckpt, queued, pg):
    """One-job fleet with given phase durations; capacity fixed at 100s x 4."""
    ivs = [
        iv("a", Phase.QUEUED, 0, queued, 4),
        iv("a", Phase.STEP, queued, queued + step, 4),
        iv("a", Phase.CHECKPOINT, queued + step, queued + step + ckpt, 4),
    ]
    return compute_goodput(ivs, 400, {"a": pg})


def test_table2_compiler_row():
    """Compiler: step time decreases -> PG up; fleet MPG rises once the
    freed device time is backfilled with more steps (device-bound row)."""
    base = _fleet(step=80, ckpt=10, queued=10, pg=0.4)
    # same work now takes 60s at PG 0.533; without backfill MPG is flat —
    # productive*pg/capacity is invariant (the paper's "no change if
    # host-bound" caveat in Table 2):
    opt_no_backfill = _fleet(step=60, ckpt=10, queued=10, pg=0.4 * 80 / 60)
    assert opt_no_backfill.pg > base.pg
    assert opt_no_backfill.mpg == pytest.approx(base.mpg)
    # with the freed 20s backfilled by more steps, MPG increases:
    opt = _fleet(step=80, ckpt=10, queued=10, pg=0.4 * 80 / 60)
    assert opt.mpg > base.mpg


def test_table2_runtime_row():
    """Runtime: off-duty (checkpoint) waste decreases -> RG up, MPG up
    (the reclaimed window runs steps), PG unchanged."""
    base = _fleet(step=80, ckpt=15, queued=5, pg=0.4)
    opt = _fleet(step=92, ckpt=3, queued=5, pg=0.4)
    assert opt.rg > base.rg
    assert opt.pg == pytest.approx(base.pg)
    assert opt.mpg > base.mpg


def test_table2_scheduler_row():
    """Scheduler: partially-allocated/queued time decreases -> SG up,
    RG/PG unchanged, MPG up."""
    base = _fleet(step=70, ckpt=10, queued=20, pg=0.4)
    opt = _fleet(step=85, ckpt=10, queued=5, pg=0.4)
    assert opt.sg > base.sg
    assert opt.pg == pytest.approx(base.pg)
    assert opt.mpg > base.mpg
