"""Whisper-medium [arXiv:2212.04356]: enc-dec, 24+24L d1024 16H (MHA kv=16)
d_ff=4096, vocab 51865; conv audio frontend STUBBED (input_specs provides
precomputed frame embeddings (b, 1500, d)).  Decoder positions extended to
32768 for the decode_32k backbone exercise (DESIGN.md §5).

Enc-dec with full attention => long_500k SKIPPED; decode shapes RUN
(decoder KV cache + cross-attention to 1500 encoder states).
"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,          # decoder layers
    encoder_layers=24,
    encoder_positions=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    norm_type="layernorm",
    mlp_activation="gelu",
    mlp_gated=False,
    tie_embeddings=True,
    qkv_bias=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, encoder_layers=2, encoder_positions=24, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128,
    attn_chunk=8, compute_dtype=jnp.float32,
)
