"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf]:
32L d4096 32H (GQA kv=8) d_ff=14336, vocab 32000.  The anyres vision tiling
is a STUB: input_specs() provides precomputed patch embeddings (b, 1152, d)
prepended to the token stream.

Full quadratic attention => long_500k SKIPPED (DESIGN.md §5).
"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_patches=1152,      # anyres 2x grid of 576-patch tiles (stubbed)
    rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128, num_patches=8, attn_chunk=8,
    compute_dtype=jnp.float32,
)
