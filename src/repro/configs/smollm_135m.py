"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M]: 30L d576 9H (GQA kv=3)
d_ff=1536, vocab 49152 -- llama-architecture small model.

Full quadratic attention => long_500k SKIPPED (DESIGN.md §5).
"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=48, num_heads=3, num_kv_heads=1, head_dim=16,
    d_ff=96, vocab_size=128, attn_chunk=8, compute_dtype=jnp.float32,
)
