"""RWKV-6 (Finch) 3B [arXiv:2404.05892; hf]: 32L d2560, attention-free,
d_ff=8960 channel-mix, vocab 65536; data-dependent per-channel decay.

Attention-free (constant-size wkv state) => ALL shapes incl. long_500k RUN.
"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=1,           # unused (attention-free)
    num_kv_heads=1,
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_dim=64,      # 40 wkv heads
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, d_ff=128, vocab_size=128,
    rwkv_head_dim=16, compute_dtype=jnp.float32,
)
