"""Qwen2-72B [arXiv:2407.10671]: 80L d8192 64H (GQA kv=8) d_ff=29568,
vocab 152064, QKV bias.

Full quadratic attention => long_500k SKIPPED (DESIGN.md §5).
"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128, attn_chunk=8, compute_dtype=jnp.float32,
)
