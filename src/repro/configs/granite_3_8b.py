"""Granite-3 8B [hf:ibm-granite/granite-3.0 family]: 40L d4096 32H (GQA kv=8)
d_ff=12800, vocab 49155.

Full quadratic attention => long_500k SKIPPED (DESIGN.md §5).
"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=129, attn_chunk=8, compute_dtype=jnp.float32,
)
