"""RecurrentGemma-2B [arXiv:2402.19427; hf]: 26L d2560 10H (MQA kv=1,
head_dim 256) d_ff=7680 (GeGLU), vocab 256000; RG-LRU + local attention
(window 2048) in a 1:2 attention:recurrent pattern.

Sub-quadratic (RG-LRU state + windowed attention) => long_500k RUNS.
"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    attention_window=2048,
    attn_every=3,            # layers 2, 5, 8, ... are attention (1:2)
    lru_width=2560,
    conv_width=4,
    mlp_activation="gelu",
    logit_softcap=30.0,
    tie_embeddings=True,
    scan_layers=False,       # heterogeneous pattern -> unrolled
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=6, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=96, vocab_size=128, attention_window=16, lru_width=64, attn_chunk=8,
    compute_dtype=jnp.float32,
)
