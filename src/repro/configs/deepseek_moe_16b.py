"""DeepSeekMoE 16B [arXiv:2401.06066; hf]: 28L d2048 16H (kv=16, MHA)
d_ff=1408 per routed expert, vocab 102400; 64 routed top-6 + 2 shared
experts (fine-grained), first layer dense (d_ff 10944 in the release; we use
the published ratio 1408*8=11264 -- backbone-equivalent FLOPs).

Full quadratic attention => long_500k SKIPPED (DESIGN.md §5).
"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    num_experts=64,
    num_shared_experts=2,
    experts_per_token=6,
    first_k_dense=1,
    d_ff_dense=11264,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=32, d_ff_dense=128, num_experts=8, experts_per_token=2,
    vocab_size=128, attn_chunk=8, compute_dtype=jnp.float32,
)
