"""Architecture registry: one module per assigned architecture.

    get_config(arch_id)   -> full published ModelConfig
    get_smoke(arch_id)    -> reduced same-family config for CPU smoke tests
    ARCH_IDS              -> all ten assigned architecture ids
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "mixtral-8x7b",
    "deepseek-moe-16b",
    "recurrentgemma-2b",
    "smollm-135m",
    "qwen2.5-14b",
    "qwen2-72b",
    "granite-3-8b",
    "rwkv6-3b",
    "llava-next-mistral-7b",
    "whisper-medium",
)

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _module(arch_id: str):
    if arch_id not in _MOD:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_MOD)}")
    return importlib.import_module(f"repro.configs.{_MOD[arch_id]}")


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str):
    return _module(arch_id).SMOKE
