"""Qwen2.5-14B [hf:Qwen family]: 48L d5120 40H (GQA kv=8) d_ff=13824,
vocab 152064, QKV bias.

Full quadratic attention => long_500k SKIPPED (DESIGN.md §5).
"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=80, num_heads=5, num_kv_heads=1, head_dim=16,
    d_ff=160, vocab_size=128, attn_chunk=8, compute_dtype=jnp.float32,
)
