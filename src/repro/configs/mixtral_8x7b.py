"""Mixtral 8x7B [arXiv:2401.04088; hf]: 32L d4096 32H (GQA kv=8) d_ff=14336
per expert, vocab 32000, MoE 8 experts top-2, sliding-window attention (4096).

Sub-quadratic via SWA => long_500k decode cell RUNS (windowed KV cache).
"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    attention_window=4096,
    num_experts=8,
    experts_per_token=2,
    rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=128, attention_window=16, attn_chunk=8,
    compute_dtype=jnp.float32,
)
