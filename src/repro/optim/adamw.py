"""Sharded AdamW (ZeRO-style: moments inherit the parameter sharding).

Pure-pytree implementation — no optax dependency.  Supports decoupled weight
decay, global-norm clipping, and optional error-feedback gradient
compression (repro.optim.compression) for cross-pod all-reduces.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None


def adamw_init(params: PyTree) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def adamw_apply(grads: PyTree, opt_state: PyTree, params: PyTree,
                cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = cfg.schedule(step) if cfg.schedule is not None else cfg.lr

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (delta + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
