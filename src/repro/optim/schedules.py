"""LR schedules (warmup-stable-decay, cosine)."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(peak: float, warmup: int, stable: int, decay: int,
                 floor: float = 0.0):
    """Warmup-Stable-Decay schedule."""

    def f(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        dec_frac = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = peak + (floor - peak) * dec_frac
        return jnp.where(step < warmup, warm, dec)

    return f


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.0):
    def f(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return f
