from repro.optim.adamw import AdamWConfig, adamw_init, adamw_apply  # noqa: F401
from repro.optim.schedules import wsd_schedule  # noqa: F401
