import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell: build the production
mesh from placeholder host devices, lower + compile the step function with
its real shardings, and record memory_analysis / cost_analysis / collective
traffic.  No arrays are ever allocated — everything is abstract.

    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # 33 cells x 2 meshes
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core import hlo_analysis
from repro.core.hardware import TPU_V5E
from repro.launch.mesh import make_production_mesh
from repro.launch.strategy import lower_cell
from repro.models.config import SHAPES, SHAPES_BY_NAME, shape_applicable

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, rules=None, cfg_override=None,
             variant: str = "baseline") -> dict:
    cfg = cfg_override or get_config(arch)
    if variant != "baseline":
        from repro.launch.variants import apply_variant

        cfg = apply_variant(cfg, variant)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, rules=rules)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = hlo_analysis.collective_stats(compiled.as_text())

    top = hlo_analysis.top_collectives(compiled.as_text(), 8)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
            "hbm_per_chip": TPU_V5E.hbm_bytes,
        },
        "cost": {
            "flops_once": cost.get("flops"),
            "bytes_once": cost.get("bytes accessed"),
        },
        "collectives": {
            "bytes_by_kind": coll.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
            "total_bytes": coll.total_bytes,
        },
        "while_trips": hlo_analysis.while_trip_counts(compiled.as_text())[:20],
        "top_collectives": top,
    }
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        suffix = "" if variant == "baseline" else f"__{variant}"
        name = f"{arch}__{shape_name}__{rec['mesh']}{suffix}.json"
        (RESULTS_DIR / name).write_text(json.dumps(rec, indent=1))
    return rec


def fits(rec) -> bool:
    m = rec.get("memory", {})
    peak = (m.get("argument_bytes") or 0) + (m.get("temp_bytes") or 0)
    return peak <= TPU_V5E.hbm_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else [s.name for s in SHAPES]
    meshes = [False, True]
    if args.multi_pod:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch:24s} {shape:12s} {'2x16x16' if mp else '16x16 '}"
                try:
                    rec = run_cell(arch, shape, mp, variant=args.variant)
                except Exception as e:  # noqa: BLE001 — report, keep sweeping
                    n_fail += 1
                    print(f"FAIL {tag}: {type(e).__name__}: {e}")
                    traceback.print_exc(limit=3)
                    continue
                if "skipped" in rec:
                    n_skip += 1
                    print(f"SKIP {tag}: {rec['skipped']}")
                    continue
                n_ok += 1
                m = rec["memory"]
                peak = ((m["argument_bytes"] or 0) + (m["temp_bytes"] or 0)) / 2**30
                print(f"OK   {tag}: compile={rec['compile_s']:7.1f}s "
                      f"peak/chip={peak:6.2f}GiB "
                      f"coll={rec['collectives']['total_bytes']/2**30:8.2f}GiB "
                      f"{'FITS' if fits(rec) else 'OVER-HBM'}")
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
