"""Serving launcher: continuous-batching decode loop with MPG accounting.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 16 --prompt-len 32 --max-new 16

Implements the serve path end-to-end: request queue -> batched prefill ->
batched decode with a shared ring-buffer KV cache -> per-request detach.
Runtime Goodput here counts decode steps as productive and queue/prefill
bubbles against RG — serving's fluctuating demand is why the paper's
Fig. 15 shows lower serve RG than training.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import model, transformer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Server:
    def __init__(self, cfg, batch: int, prompt_len: int, max_len: int):
        self.cfg = cfg
        self.batch = batch
        self.params = model.init_params(cfg, jax.random.key(0))
        self.prefill = jax.jit(
            lambda p, b: transformer.prefill(p, b, cfg, max_len=max_len)
            if cfg.family != "encdec" else model.prefill_fn(cfg)(p, b))
        self.decode = jax.jit(model.decode_fn(cfg))

    def run_batch(self, reqs: List[Request]):
        toks = np.stack([r.prompt for r in reqs])
        t0 = time.monotonic()
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (len(reqs), self.cfg.num_patches, self.cfg.d_model),
                self.cfg.compute_dtype)
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (len(reqs), self.cfg.encoder_positions, self.cfg.d_model),
                self.cfg.compute_dtype)
        logits, cache = self.prefill(self.params, batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        t_prefill = time.monotonic() - t0
        for r, t in zip(reqs, np.asarray(tok)):
            r.out_tokens.append(int(t))
            r.t_first = time.monotonic()
        max_new = max(r.max_new for r in reqs)
        t1 = time.monotonic()
        for _ in range(max_new - 1):
            logits, cache = self.decode(self.params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            for r, t in zip(reqs, np.asarray(tok)):
                if len(r.out_tokens) < r.max_new:
                    r.out_tokens.append(int(t))
        jax.block_until_ready(tok)
        t_decode = time.monotonic() - t1
        for r in reqs:
            r.t_done = time.monotonic()
        return t_prefill, t_decode


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).astype(np.int32),
                    args.max_new, t_submit=time.monotonic())
            for i in range(args.requests)]
    server = Server(cfg, args.batch, args.prompt_len,
                    max_len=args.prompt_len + args.max_new)

    t_pre = t_dec = 0.0
    for i in range(0, len(reqs), args.batch):
        group = reqs[i:i + args.batch]
        if len(group) < args.batch:   # pad the tail batch
            group = group + group[: args.batch - len(group)]
        p, d = server.run_batch(group[: args.batch])
        t_pre += p
        t_dec += d

    done = [r for r in reqs if r.t_done]
    toks = sum(len(r.out_tokens) for r in done)
    wall = max(r.t_done for r in done) - min(r.t_submit for r in done)
    ttft = float(np.mean([r.t_first - r.t_submit for r in done]))
    print(json.dumps({
        "arch": cfg.name,
        "requests": len(done),
        "tokens_generated": toks,
        "throughput_tok_s": round(toks / wall, 2),
        "mean_ttft_s": round(ttft, 4),
        "prefill_s": round(t_pre, 3),
        "decode_s": round(t_dec, 3),
    }, indent=1))


if __name__ == "__main__":
    main()
