"""Serving launcher: continuous-batching decode loop with MPG accounting.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 16 --prompt-len 32 --max-new 16

Implements the serve path end-to-end: request queue -> batched prefill ->
batched decode with a shared ring-buffer KV cache -> per-request detach.

Accounting streams into the same ``GoodputLedger`` the fleet simulator and
training orchestrator use — one fleet-wide MPG sink across all three stack
layers (paper §4).  Each batch slot is accounted like a chip: queue wait is
QUEUED, prefill is INIT, decode iterations a request actually uses are
STEP, and batch bubbles — padded tail slots and early-finished requests
riding out the longest request's decode — are IDLE.  Serving's fluctuating
demand is why the paper's Fig. 15 shows lower serve RG than training.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.core.goodput import Layer, Phase
from repro.core.ledger import GoodputLedger
from repro.models import model, transformer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def is_pad(self) -> bool:
        """Sentinel clones that fill a tail batch; excluded from metrics."""
        return self.rid < 0


def pad_group(group: List[Request], batch: int) -> List[Request]:
    """Pad a tail batch to full width with sentinel clones.

    The clones share prompts (the compiled program needs a full batch of
    real token ids) but carry ``rid=-1`` and their *own* ``out_tokens``
    lists, so ``run_batch`` neither appends generated tokens to a real
    request twice nor overwrites its ``t_first``/``t_done`` — the
    double-counted ``tokens_generated``/``throughput_tok_s`` bug.
    """
    pads = [Request(rid=-1, prompt=group[i % len(group)].prompt,
                    max_new=group[i % len(group)].max_new)
            for i in range(batch - len(group))]
    return group + pads


class TickClock:
    """Deterministic stand-in for ``time.monotonic``: each call advances a
    fixed virtual dt.  Injecting one makes the serve emitter's interval
    stream reproducible (the determinism-audit fix for wall-clock reads),
    so serve-layer traces can be recorded and replayed like fleet ones."""

    def __init__(self, dt: float = 1.0, t0: float = 0.0):
        self.dt = dt
        self.t = t0

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


class Server:
    def __init__(self, cfg, batch: int, prompt_len: int, max_len: int,
                 ledger: Optional[GoodputLedger] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.batch = batch
        self.clock = clock
        self.ledger = ledger if ledger is not None else GoodputLedger()
        self.params = model.init_params(cfg, jax.random.key(0))
        self.prefill = jax.jit(
            lambda p, b: transformer.prefill(p, b, cfg, max_len=max_len)
            if cfg.family != "encdec" else model.prefill_fn(cfg)(p, b))
        self.decode = jax.jit(model.decode_fn(cfg))

    def _emit(self, rid: int, phase: Phase, t0: float, t1: float,
              layer: Layer, chips: int = 1):
        self.ledger.emit(job_id=f"req{rid}" if rid >= 0 else "pad",
                         phase=phase, t0=t0, t1=t1, chips=chips,
                         segment={"phase_kind": "serve",
                                  "arch": self.cfg.name,
                                  "emitter": "serve",
                                  "layer": layer.value})

    def run_batch(self, reqs: List[Request]) -> Tuple[float, float]:
        real = [r for r in reqs if not r.is_pad]
        n_pad = len(reqs) - len(real)
        toks = np.stack([r.prompt for r in reqs])
        t0 = self.clock()
        for r in real:                       # queue wait: submit -> batch
            self._emit(r.rid, Phase.QUEUED, r.t_submit, t0,
                       layer=Layer.SCHEDULING)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (len(reqs), self.cfg.num_patches, self.cfg.d_model),
                self.cfg.compute_dtype)
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (len(reqs), self.cfg.encoder_positions, self.cfg.d_model),
                self.cfg.compute_dtype)
        logits, cache = self.prefill(self.params, batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        t_prefill = self.clock() - t0
        for r, t in zip(reqs, np.asarray(tok)):
            r.out_tokens.append(int(t))
            if not r.is_pad:
                r.t_first = self.clock()
        # prefill is program setup for the batch: INIT for live slots
        # (model-layer warmup — real forward compute, not a compile), and
        # IDLE for the padded ones (a batch-shape bubble the batching
        # policy — the scheduling layer — is responsible for)
        self._emit(real[0].rid if real else -1, Phase.INIT,
                   t0, t0 + t_prefill, layer=Layer.MODEL, chips=len(real))
        if n_pad:
            self._emit(-1, Phase.IDLE, t0, t0 + t_prefill,
                       layer=Layer.SCHEDULING, chips=n_pad)
        max_new = max(r.max_new for r in reqs)
        t1 = self.clock()
        for _ in range(max_new - 1):
            logits, cache = self.decode(self.params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            for r, t in zip(reqs, np.asarray(tok)):
                if len(r.out_tokens) < r.max_new:
                    r.out_tokens.append(int(t))
        jax.block_until_ready(tok)
        t_decode = self.clock() - t1
        t2 = t1 + t_decode
        iters = max(max_new - 1, 1)
        for r in real:
            r.t_done = self.clock()
            # STEP for the decode iterations this request consumed, IDLE
            # for the bubble riding out the batch's longest request
            frac = (len(r.out_tokens) - 1) / iters
            split = t1 + frac * t_decode
            self._emit(r.rid, Phase.STEP, t1, split, layer=Layer.MODEL)
            self._emit(r.rid, Phase.IDLE, split, t2,
                       layer=Layer.SCHEDULING)
        if n_pad:
            self._emit(-1, Phase.IDLE, t1, t2, layer=Layer.SCHEDULING,
                       chips=n_pad)
        return t_prefill, t_decode


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).astype(np.int32),
                    args.max_new, t_submit=time.monotonic())
            for i in range(args.requests)]
    ledger = GoodputLedger(window=60.0)
    server = Server(cfg, args.batch, args.prompt_len,
                    max_len=args.prompt_len + args.max_new, ledger=ledger)

    t_pre = t_dec = 0.0
    for i in range(0, len(reqs), args.batch):
        group = pad_group(reqs[i:i + args.batch], args.batch)
        p, d = server.run_batch(group)
        t_pre += p
        t_dec += d

    done = [r for r in reqs if r.t_done]
    toks = sum(len(r.out_tokens) for r in done)
    wall = max(r.t_done for r in done) - min(r.t_submit for r in done)
    ttft = float(np.mean([r.t_first - r.t_submit for r in done]))
    rep = ledger.report(capacity_chip_time=args.batch * wall)
    print(json.dumps({
        "arch": cfg.name,
        "requests": len(done),
        "tokens_generated": toks,
        "throughput_tok_s": round(toks / wall, 2),
        "mean_ttft_s": round(ttft, 4),
        "prefill_s": round(t_pre, 3),
        "decode_s": round(t_dec, 3),
        "serve_rg": round(rep.rg, 4),
        "rg_breakdown": {k: round(v, 4)
                         for k, v in ledger.rg_breakdown().items()},
    }, indent=1))


if __name__ == "__main__":
    main()
