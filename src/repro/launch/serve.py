"""Serving launcher: continuous-batching inference engine with MPG + SLO
accounting.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 16 --prompt-len 32 --max-new 16

Two engines share one accounting contract (``repro.core.ledger``):

  * ``--engine continuous`` (default): the production path —
    ``repro.serve.ContinuousServeEngine`` driving the real model through
    the batched paged-decode executor
    (``repro.serve.batched_executor``, one jitted decode over the
    allocator's block tables) or the per-slot fallback
    (``repro.serve.jax_executor``, ``--executor slot`` or families that
    resist paging), with per-iteration admission, immediate detach, a
    paged KV-cache allocator, and a latency SLO whose breaches book as
    scheduling-layer losses;
  * ``--engine static``: the legacy fixed-group batch loop (``Server``
    below), kept as the measured baseline the A/B benchmarks compare
    against.

Each batch slot is accounted like a chip: queue wait is QUEUED, prefill
is INIT, decode iterations a request actually uses are STEP (or
SLO_BREACH past its deadline), and batch bubbles are IDLE.  Serving's
fluctuating demand is why the paper's Fig. 15 shows lower serve RG than
training.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.core.goodput import Layer, Phase
from repro.core.ledger import GoodputLedger
from repro.models import model, transformer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def is_pad(self) -> bool:
        """Sentinel clones that fill a tail batch; excluded from metrics."""
        return self.rid < 0


def pad_group(group: List[Request], batch: int) -> List[Request]:
    """Pad a tail batch to full width with sentinel clones.

    The clones share prompts (the compiled program needs a full batch of
    real token ids) but carry ``rid=-1`` and their *own* ``out_tokens``
    lists, so ``run_batch`` neither appends generated tokens to a real
    request twice nor overwrites its ``t_first``/``t_done`` — the
    double-counted ``tokens_generated``/``throughput_tok_s`` bug.
    """
    if not group:
        # the modulo clone-source cycle below would divide by zero; an
        # all-pad batch also has no real prompts to clone from
        raise ValueError("cannot pad an empty request group")
    pads = [Request(rid=-1, prompt=group[i % len(group)].prompt,
                    max_new=group[i % len(group)].max_new)
            for i in range(batch - len(group))]
    return group + pads


class TickClock:
    """Deterministic stand-in for ``time.monotonic``: each call advances a
    fixed virtual dt.  Injecting one makes the serve emitter's interval
    stream reproducible (the determinism-audit fix for wall-clock reads),
    so serve-layer traces can be recorded and replayed like fleet ones."""

    def __init__(self, dt: float = 1.0, t0: float = 0.0):
        self.dt = dt
        self.t = t0

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


class Server:
    """The static fixed-group batch loop (the measured A/B baseline).

    Clock discipline: ``run_batch`` reads ``self.clock`` exactly once per
    phase boundary (batch start, prefill end, decode end) so an injected
    ``TickClock`` advances identically on every same-seed run, and
    ``t_first``/``t_done`` land *inside* the emitted intervals — the
    serve-clock-skew fix.  Each slot's INIT/STEP/IDLE intervals exactly
    tile ``[t0, t2]`` (asserted per batch).
    """

    def __init__(self, cfg, batch: int, max_len: int,
                 ledger: Optional[GoodputLedger] = None,
                 clock: Callable[[], float] = time.monotonic):
        if batch <= 0:
            raise ValueError(f"batch must be positive, got {batch}")
        self.cfg = cfg
        self.batch = batch
        self.clock = clock
        self.ledger = ledger if ledger is not None else GoodputLedger()
        self.params = model.init_params(cfg, jax.random.key(0))
        self.prefill = jax.jit(
            lambda p, b: transformer.prefill(p, b, cfg, max_len=max_len)
            if cfg.family != "encdec" else model.prefill_fn(cfg)(p, b))
        self.decode = jax.jit(model.decode_fn(cfg))
        # ledger-time-base span of all emitted batches, for the capacity
        # denominator (SG): request wall-clock timestamps are the wrong
        # time base once a virtual clock is injected
        self._t_start: Optional[float] = None
        self._t_end: Optional[float] = None

    def capacity_chip_time(self) -> float:
        """Slot-chips x the ledger-time span this server was serving —
        the SG denominator, derived from the same clock the emitted
        intervals use (never from request timestamps)."""
        if self._t_start is None or self._t_end is None:
            return 0.0
        return self.batch * max(0.0, self._t_end - self._t_start)

    def span(self) -> float:
        if self._t_start is None or self._t_end is None:
            return 0.0
        return max(0.0, self._t_end - self._t_start)

    def _emit(self, rid: int, phase: Phase, t0: float, t1: float,
              layer: Layer, chips: int = 1):
        self.ledger.emit(job_id=f"req{rid}" if rid >= 0 else "pad",
                         phase=phase, t0=t0, t1=t1, chips=chips,
                         segment={"phase_kind": "serve",
                                  "arch": self.cfg.name,
                                  "emitter": "serve",
                                  "layer": layer.value})

    def run_batch(self, reqs: List[Request]) -> Tuple[float, float]:
        if len(reqs) != self.batch:
            raise ValueError(
                f"run_batch needs exactly batch={self.batch} slots, got "
                f"{len(reqs)} — pad tail groups with pad_group()")
        real = [r for r in reqs if not r.is_pad]
        n_pad = len(reqs) - len(real)
        if not real:
            raise ValueError("run_batch needs at least one real request")
        toks = np.stack([r.prompt for r in reqs])
        t0 = self.clock()                    # boundary 1: batch start
        for r in real:                       # queue wait: submit -> batch
            self._emit(r.rid, Phase.QUEUED, r.t_submit, t0,
                       layer=Layer.SCHEDULING)
        start_len = [len(r.out_tokens) for r in reqs]
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (len(reqs), self.cfg.num_patches, self.cfg.d_model),
                self.cfg.compute_dtype)
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (len(reqs), self.cfg.encoder_positions, self.cfg.d_model),
                self.cfg.compute_dtype)
        logits, cache = self.prefill(self.params, batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        t1 = self.clock()                    # boundary 2: prefill end
        for r, t in zip(reqs, np.asarray(tok)):
            r.out_tokens.append(int(t))
            if not r.is_pad:
                r.t_first = t1               # first token lands here
        max_new = max(r.max_new for r in reqs)
        for _ in range(max_new - 1):
            logits, cache = self.decode(self.params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            for r, t in zip(reqs, np.asarray(tok)):
                if len(r.out_tokens) < r.max_new:
                    r.out_tokens.append(int(t))
        jax.block_until_ready(tok)
        t2 = self.clock()                    # boundary 3: decode end
        t_prefill = t1 - t0
        t_decode = t2 - t1
        iters = max(max_new - 1, 1)
        gen = {id(r): len(r.out_tokens) - s for r, s in zip(reqs, start_len)}
        for r in real:
            r.t_done = t2
            # prefill is program setup for the batch: INIT for live slots
            # (model-layer warmup — real forward compute, not a compile);
            # STEP for the decode iterations this request consumed, IDLE
            # for the bubble riding out the batch's longest request
            frac = min(1.0, max(0, gen[id(r)] - 1) / iters)
            split = t1 + frac * t_decode
            self._assert_tiles(t0, (t0, t1, split, t2), t2)
            self._emit(r.rid, Phase.INIT, t0, t1, layer=Layer.MODEL)
            self._emit(r.rid, Phase.STEP, t1, split, layer=Layer.MODEL)
            self._emit(r.rid, Phase.IDLE, split, t2,
                       layer=Layer.SCHEDULING)
        if n_pad:
            # padded slots: a batch-shape bubble the batching policy —
            # the scheduling layer — is responsible for
            self._emit(-1, Phase.IDLE, t0, t2, layer=Layer.SCHEDULING,
                       chips=n_pad)
        if self._t_start is None:
            self._t_start = t0
        self._t_end = t2
        return t_prefill, t_decode

    @staticmethod
    def _assert_tiles(t0: float, bounds: Tuple[float, ...], t2: float):
        """Each slot's interval boundaries must tile [t0, t2]: start at
        t0, end at t2, monotone non-decreasing — no gap, no overlap
        (zero-width segments are legal boundaries, not gaps)."""
        assert bounds[0] == t0 and bounds[-1] == t2, \
            f"slot intervals do not span [{t0}, {t2}]: {bounds}"
        for a, b in zip(bounds, bounds[1:]):
            assert a <= b, f"slot interval boundaries regress: {bounds}"


def run_static_server(cfg, reqs: List[Request], batch: int, max_new: int,
                      prompt_len: int,
                      ledger: Optional[GoodputLedger] = None,
                      clock: Callable[[], float] = time.monotonic
                      ) -> Tuple["Server", dict]:
    """Drive the legacy fixed-group loop and summarize it (CLI + tests)."""
    ledger = ledger if ledger is not None else GoodputLedger(window=60.0)
    server = Server(cfg, batch, max_len=prompt_len + max_new,
                    ledger=ledger, clock=clock)
    t_pre = t_dec = 0.0
    for i in range(0, len(reqs), batch):
        group = pad_group(reqs[i:i + batch], batch)
        p, d = server.run_batch(group)
        t_pre += p
        t_dec += d
    done = [r for r in reqs if r.out_tokens]
    toks = sum(len(r.out_tokens) for r in done)
    wall = server.span()
    ttft = (float(np.mean([r.t_first - r.t_submit for r in done]))
            if done else 0.0)
    rep = ledger.report(capacity_chip_time=server.capacity_chip_time())
    return server, {
        "engine": "static",
        "arch": cfg.name,
        "requests": len(done),
        "tokens_generated": toks,
        "throughput_tok_s": round(toks / wall, 2) if wall > 0 else 0.0,
        "mean_ttft_s": round(ttft, 4),
        "prefill_s": round(t_pre, 3),
        "decode_s": round(t_dec, 3),
        "capacity_chip_time": server.capacity_chip_time(),
        "serve_sg": round(rep.sg, 4),
        "serve_rg": round(rep.rg, 4),
        "rg_breakdown": {k: round(v, 4)
                         for k, v in ledger.rg_breakdown().items()},
    }


def run_continuous_server(cfg, reqs: List[Request], batch: int,
                          max_new: int, prompt_len: int,
                          slo_ttft: float, slo_tpot: float,
                          kv_block_tokens: int = 0,
                          clock: Callable[[], float] = time.monotonic,
                          executor_kind: str = "auto") -> dict:
    """Drive the continuous engine over the real model and return its
    ServeReport dict.  ``executor_kind``: "batched" decodes every live
    slot in one jitted call over the paged KV pool, "slot" runs the
    per-slot batch-1 fallback, "auto" picks batched when the family
    supports paged decode."""
    from repro.models import model as _model
    from repro.serve import (ContinuousServeEngine, PagedKVCache,
                             ServeRequest, ServeSLO)

    slo = ServeSLO(ttft=slo_ttft if slo_ttft > 0 else float("inf"),
                   tpot=slo_tpot if slo_tpot > 0 else float("inf"))
    max_len = prompt_len + max_new
    use_batched = executor_kind == "batched" or (
        executor_kind == "auto"
        and _model.supports_paged_decode(cfg, max_len))
    if use_batched:
        from repro.serve.batched_executor import JaxBatchedExecutor

        # the batched executor's allocator IS the engine's kv cache
        # (block_tokens pinned to the kernel kv tile, so kv_block_tokens
        # is ignored on this path)
        executor = JaxBatchedExecutor(cfg, max_len, batch, clock=clock)
        kv = executor.kv
    else:
        from repro.serve.jax_executor import JaxSlotExecutor

        block_tokens = kv_block_tokens or min(128, prompt_len + max_new)
        need_blocks = -(-(prompt_len + max_new) // block_tokens)
        kv = PagedKVCache(n_blocks=batch * need_blocks,
                          block_tokens=block_tokens)
        executor = JaxSlotExecutor(cfg, max_len=max_len, clock=clock)
    engine = ContinuousServeEngine(batch, executor, slo=slo, kv_cache=kv,
                                   ledger=GoodputLedger(window=60.0),
                                   arch=cfg.name)
    serve_reqs = [ServeRequest(rid=r.rid, prompt_len=len(r.prompt),
                               max_new=r.max_new, t_submit=r.t_submit,
                               prompt=r.prompt)
                  for r in reqs]
    report = engine.run(serve_reqs)
    # reflect results back onto the caller's Request objects
    by_rid = {r.rid: r for r in serve_reqs}
    for r in reqs:
        sr = by_rid[r.rid]
        r.out_tokens = sr.out_tokens
        r.t_first, r.t_done = sr.t_first, sr.t_done
    out = report.as_dict()
    out["arch"] = cfg.name
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="continuous",
                    choices=("continuous", "static"))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--span", type=float, default=0.0,
                    help="spread request arrivals over this many seconds "
                         "of the serve timeline (0 = all at t=0)")
    ap.add_argument("--arrival", default="uniform",
                    choices=("uniform", "diurnal", "bursty"),
                    help="arrival modulation over --span (the fleet "
                         "scenario processes, repro.fleet.scenarios)")
    ap.add_argument("--executor", default="auto",
                    choices=("auto", "batched", "slot"),
                    help="continuous-engine executor: one jitted batched "
                         "paged decode vs per-slot batch-1 (auto picks "
                         "batched when the family supports paged decode)")
    ap.add_argument("--slo-ttft", type=float, default=0.0,
                    help="time-to-first-token SLO in seconds (0 = none)")
    ap.add_argument("--slo-tpot", type=float, default=0.0,
                    help="per-output-token SLO in seconds (0 = none)")
    ap.add_argument("--tick-dt", type=float, default=0.0,
                    help="inject a TickClock with this dt (deterministic "
                         "virtual time; 0 = wall clock)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    clock = TickClock(dt=args.tick_dt) if args.tick_dt > 0 \
        else time.monotonic
    rng = np.random.default_rng(args.seed)
    if args.span > 0:
        from repro.fleet.scenarios import SCENARIOS, request_arrivals
        mod = {"uniform": SCENARIOS["steady"],
               "diurnal": SCENARIOS["diurnal"],
               "bursty": SCENARIOS["bursty"]}[args.arrival].arrival
        arrivals = request_arrivals(args.requests, args.span,
                                    seed=args.seed, arrival=mod)
    else:
        arrivals = [0.0] * args.requests
    # Arrivals are offsets from the start of the serve timeline; anchor
    # them to the clock actually driving the server so t_submit shares a
    # time base with the emitted intervals (wall clock reads machine
    # uptime, not zero).
    t_base = clock()
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).astype(np.int32),
                    args.max_new, t_submit=t_base + arrivals[i])
            for i in range(args.requests)]

    if args.engine == "continuous":
        out = run_continuous_server(
            cfg, reqs, args.batch, args.max_new, args.prompt_len,
            slo_ttft=args.slo_ttft, slo_tpot=args.slo_tpot, clock=clock,
            executor_kind=args.executor)
    else:
        _, out = run_static_server(cfg, reqs, args.batch, args.max_new,
                                   args.prompt_len, clock=clock)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
