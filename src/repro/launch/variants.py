"""Named optimization variants for §Perf hillclimbing.

Each variant is a config transform applied on top of the paper-faithful
baseline; the dry-run CLI (--variant) and benchmarks/perf_iters.py resolve
them here so every measurement names exactly what changed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax.numpy as jnp

from repro.models.config import ModelConfig


def _v(**kw) -> Callable[[ModelConfig], ModelConfig]:
    return lambda cfg: dataclasses.replace(cfg, **kw)


VARIANTS: Dict[str, Callable[[ModelConfig], ModelConfig]] = {
    "baseline": lambda cfg: cfg,
    # MoE dispatch: GSPMD sort/scatter -> explicit shard_map EP/TP
    "moe_shard_map": _v(moe_impl="ep"),
    # gradient-accumulation microbatching (activation-memory lever)
    "microbatch2": _v(microbatches=2),
    "microbatch4": _v(microbatches=4),
    "microbatch8": _v(microbatches=8),
    # seq-chunked cross-entropy (logits-memory lever)
    "loss_chunk512": _v(loss_chunk=512),
    # smaller attention query blocks (VMEM/live-buffer lever)
    "attn_chunk512": _v(attn_chunk=512),
    "attn_chunk2048": _v(attn_chunk=2048),
    # no sequence parallelism (ablation: what SP buys)
    "no_sp": _v(seq_shard_activations=False),
    # no remat (ablation: memory/compute trade)
    "no_remat": _v(remat=False),
    # collective-term levers (EXPERIMENTS §Perf, qwen2-72b train diagnosis)
    "kv_gather": _v(attn_kv_gather=True),
    "bf16_grads": _v(bf16_grad_reduce=True),
    # combos used in §Perf
    "mb4_losschunk": _v(microbatches=4, loss_chunk=512),
    "moe_sm_mb4": _v(moe_impl="ep", microbatches=4),
    "moe_sm_mb4_losschunk": _v(moe_impl="ep", microbatches=4,
                               loss_chunk=512),
    "moe_sm_losschunk": _v(moe_impl="ep", loss_chunk=512),
    "kv_bf16": _v(attn_kv_gather=True, bf16_grad_reduce=True),
    # kv_gather REFUTED for train (gathered kv held live in bwd: +34 GiB;
    # see EXPERIMENTS §Perf) — dense_opt uses bf16 grads + mb + loss chunk.
    "dense_opt": _v(bf16_grad_reduce=True, microbatches=4, loss_chunk=512),
    "moe_opt": _v(moe_impl="ep", bf16_grad_reduce=True, microbatches=4,
                  loss_chunk=512),
    "kvg_opt": _v(attn_kv_gather=True, bf16_grad_reduce=True,
                  microbatches=4, loss_chunk=512),
    # comm-neutral memory levers (no microbatching: 1x gathers/reduces)
    "lc_ac512": _v(loss_chunk=512, attn_chunk=512, bf16_grad_reduce=True),
    "mb2_lc": _v(microbatches=2, loss_chunk=512, bf16_grad_reduce=True),
    "mb8_lc": _v(microbatches=8, loss_chunk=512, bf16_grad_reduce=True),
    # serving: bf16 checkpoint weights (standard for inference)
    "serve_bf16": _v(param_dtype=jnp.bfloat16),
    "decode_unrolled": _v(decode_unroll=True),
    "decode_opt": _v(decode_unroll=True, param_dtype=jnp.bfloat16),
}


def apply_variant(cfg: ModelConfig, name: str) -> ModelConfig:
    return VARIANTS[name](cfg)
