"""Step-function assembly: jitted train / prefill / decode with explicit
in/out shardings for a given (arch config x input shape x mesh) cell.

This is the seam between the model zoo and the distribution layer — the
dry-run, the trainer, and the server all build their step functions here so
every entry point uses identical sharding decisions.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.init import abstract_params
from repro.optim import AdamWConfig, adamw_apply, adamw_init
from repro.parallel import sharding as shlib
from repro.parallel.ctx import ParallelCtx, parallel_ctx

PyTree = Any


def make_ctx(cfg: ModelConfig, mesh: Mesh) -> ParallelCtx:
    return ParallelCtx(
        mesh,
        dp_axes=("pod", "data"),
        tp_axis="model",
        sp_axis="model" if cfg.seq_shard_activations else None,
        bf16_grad=cfg.bf16_grad_reduce,
    )


def named(mesh, tree_of_pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    lfn = model.loss_fn(cfg)

    def cast(p):
        # Mixed precision: differentiate wrt bf16 copies so FSDP gathers and
        # gradient reductions move bf16, not fp32 (2x collective-term win);
        # fp32 master weights live only in the optimizer update.
        if p.dtype == jnp.float32 and p.ndim > 1:
            return p.astype(cfg.compute_dtype)
        return p

    mb = max(1, cfg.microbatches)

    def constrain_grads(grads):
        """Pin grads to their final sharding while still bf16: otherwise
        XLA sinks the data-parallel all-reduce below the optimizer's
        astype(f32) and reduces in fp32 (2x bytes) — EXPERIMENTS §Perf."""
        if not cfg.bf16_grad_reduce:
            return grads
        from repro.parallel.ctx import get_ctx
        from repro.parallel.sharding import param_pspecs

        ctx = get_ctx()
        if ctx is None:
            return grads
        specs = param_pspecs(cfg, ctx.mesh)
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, jax.sharding.NamedSharding(ctx.mesh, s)),
            grads, specs)

    def train_step(state, batch):
        params_c = jax.tree.map(cast, state["params"])
        if mb > 1:
            # gradient accumulation: fp32 grad buffer, one optimizer step
            split = jax.tree.map(
                lambda a: a.reshape(mb, a.shape[0] // mb, *a.shape[1:]),
                batch)

            def body(carry, mbatch):
                acc, loss_acc = carry
                (loss, _), grads = jax.value_and_grad(
                    lfn, has_aux=True)(params_c, mbatch)
                grads = constrain_grads(grads)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params_c)
            (gsum, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), split)
            grads = jax.tree.map(lambda g: g / mb, gsum)
            loss = loss_sum / mb
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                lfn, has_aux=True)(params_c, batch)
            grads = constrain_grads(grads)
        new_p, new_opt, om = adamw_apply(grads, state["opt"],
                                         state["params"], opt_cfg)
        return ({"params": new_p, "opt": new_opt},
                {"loss": loss, **metrics, **om})

    return train_step


def abstract_train_state(cfg: ModelConfig) -> PyTree:
    params = abstract_params(cfg)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {
        "params": params,
        "opt": {
            "m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        },
    }


def train_state_shardings(cfg: ModelConfig, mesh: Mesh, rules=None) -> PyTree:
    pshard = shlib.param_shardings(cfg, mesh, rules)
    return {
        "params": pshard,
        "opt": {
            "m": pshard,
            "v": pshard,
            "step": NamedSharding(mesh, P()),
        },
    }


def init_train_state(cfg: ModelConfig, key, mesh: Optional[Mesh] = None):
    params = model.init_params(cfg, key)
    state = {"params": params, "opt": adamw_init(params)}
    if mesh is not None:
        shards = train_state_shardings(cfg, mesh)
        state = jax.device_put(state, shards)
    return state


def jit_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   opt_cfg: Optional[AdamWConfig] = None, rules=None):
    """Returns (jitted_fn, abstract_args, ctx). Lower with fn.lower(*args)."""
    opt_cfg = opt_cfg or AdamWConfig()
    step = make_train_step(cfg, opt_cfg)
    state_sh = train_state_shardings(cfg, mesh, rules)
    batch_abs = model.input_specs(cfg, shape)
    batch_sh = named(mesh, shlib.batch_pspecs(cfg, batch_abs, mesh))
    fn = jax.jit(step,
                 in_shardings=(state_sh, batch_sh),
                 out_shardings=(state_sh, None),
                 donate_argnums=(0,))
    return fn, (abstract_train_state(cfg), batch_abs), make_ctx(cfg, mesh)


# ---------------------------------------------------------------------------
# Serve: prefill + decode
# ---------------------------------------------------------------------------

def jit_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     rules=None):
    pfn = model.prefill_fn(cfg)

    def prefill_step(params, batch):
        return pfn(params, batch)

    param_sh = shlib.param_shardings(cfg, mesh, rules)
    batch_abs = model.input_specs(cfg, shape)
    batch_sh = named(mesh, shlib.batch_pspecs(cfg, batch_abs, mesh))
    fn = jax.jit(prefill_step, in_shardings=(param_sh, batch_sh))
    return fn, (abstract_params(cfg), batch_abs), make_ctx(cfg, mesh)


def jit_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rules=None):
    dfn = model.decode_fn(cfg)
    param_sh = shlib.param_shardings(cfg, mesh, rules)
    specs = model.input_specs(cfg, shape)
    tok_abs, cache_abs = specs["token"], specs["cache"]
    tok_sh = named(mesh, shlib.batch_pspecs(cfg, tok_abs, mesh))
    cache_sh = named(mesh, shlib.cache_pspecs(cfg, cache_abs, mesh))
    fn = jax.jit(dfn,
                 in_shardings=(param_sh, tok_sh, cache_sh),
                 out_shardings=(None, cache_sh),
                 donate_argnums=(2,))
    return fn, (abstract_params(cfg), tok_abs, cache_abs), make_ctx(cfg, mesh)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules=None):
    """Lower one assignment cell under its ParallelCtx. Returns Lowered."""
    if shape.kind == "train":
        fn, args, ctx = jit_train_step(cfg, shape, mesh, rules=rules)
    elif shape.kind == "prefill":
        fn, args, ctx = jit_prefill_step(cfg, shape, mesh, rules=rules)
    else:
        fn, args, ctx = jit_decode_step(cfg, shape, mesh, rules=rules)
    with parallel_ctx(ctx):
        return fn.lower(*args)
