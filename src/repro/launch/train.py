"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
        --steps 200 --batch 8 --seq 128

Runs the MPG-instrumented orchestrator (checkpoint/restart, async ckpt,
AOT cache) on CPU for smoke-scale configs; on a real TPU slice the same
entry point builds the production mesh and sharded step function.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import tempfile

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.core.goodput import compute_goodput, rg_breakdown
from repro.runtime.orchestrator import Orchestrator, RunConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--async-checkpoint", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--preempt-at", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    run = RunConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                    checkpoint_every=args.checkpoint_every,
                    async_checkpoint=args.async_checkpoint,
                    ckpt_dir=ckpt_dir, preempt_at_step=args.preempt_at,
                    job_id=f"train-{args.arch}")
    orc = Orchestrator(cfg, run)
    out = orc.run()

    total = sum(i.chip_time for i in orc.intervals)
    rep = compute_goodput(orc.intervals, total)
    print(json.dumps({
        "arch": args.arch,
        "steps": [out["start_step"], out["end_step"]],
        "final_loss": out["losses"][-1] if out["losses"] else None,
        "runtime_goodput": round(rep.rg, 4),
        "rg_breakdown": {k: round(v, 4)
                         for k, v in rg_breakdown(orc.intervals).items()},
        "ckpt": out["ckpt_metrics"],
        "compile_s": round(out["compile_s"], 2),
        "ckpt_dir": ckpt_dir,
    }, indent=1))


if __name__ == "__main__":
    main()
