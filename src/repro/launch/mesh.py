"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small meshes for tests (must divide the available device count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_chips(mesh) -> int:
    return mesh.devices.size
