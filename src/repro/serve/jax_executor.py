"""Real-model executor for the continuous-batching engine.

Per-slot batch-1 execution: each live request owns its own batch-1
decode cache, so admission and detach are cache-dict inserts/removes —
no recompilation, no cross-slot position coupling (the dense ring cache
shares one scalar ``pos`` across a batch, which is exactly what forbids
mid-flight admission into a *batched* cache).  ``prefill`` and
``decode`` are jitted once at batch width 1 and reused for every slot.

This trades MXU batching efficiency for exact continuous-batching
semantics with the real program — the right trade for smoke-scale
correctness runs.  Throughput modeling at scale lives in
:class:`repro.serve.engine.SimulatedExecutor`; batched paged-attention
decode over the block tables (the Pallas flash-attention kernel's
``block_k`` tiles, which the allocator's block size mirrors) is the
hardware path this executor stands in for.

Costs are measured off an injectable clock (``TickClock`` for
deterministic tests, ``time.monotonic`` for real runs), read once per
op — the same one-read-per-boundary contract as the fixed legacy server.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model, transformer


class JaxSlotExecutor:
    def __init__(self, cfg, max_len: int,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.max_len = max_len
        self.clock = clock
        self.params = model.init_params(cfg, jax.random.key(0))
        self._prefill = jax.jit(
            lambda p, b: transformer.prefill(p, b, cfg, max_len=max_len)
            if cfg.family != "encdec" else model.prefill_fn(cfg)(p, b))
        self._decode = jax.jit(model.decode_fn(cfg))
        self._caches: Dict[int, object] = {}
        self._tok: Dict[int, object] = {}

    def _batch1(self, req) -> Dict[str, object]:
        if req.prompt is None:
            raise ValueError(f"request {req.rid} carries no prompt tokens")
        batch = {"tokens": jnp.asarray(np.asarray(req.prompt)[None, :])}
        cfg = self.cfg
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (1, cfg.num_patches, cfg.d_model), cfg.compute_dtype)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (1, cfg.encoder_positions, cfg.d_model), cfg.compute_dtype)
        return batch

    def prefill(self, reqs: Sequence) -> Tuple[List[int], float]:
        t0 = self.clock()
        pend = []
        for r in reqs:
            logits, cache = self._prefill(self.params, self._batch1(r))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            self._caches[r.rid] = cache
            self._tok[r.rid] = tok
            pend.append(tok)
        # issue every slot's computation first, then ONE host sync before
        # reading the clock — a per-slot int() would serialize N device
        # round-trips into the measured cost
        if pend:
            jax.block_until_ready(pend)
        cost = max(0.0, self.clock() - t0)
        return [int(t[0]) for t in pend], cost

    def decode(self, reqs: Sequence) -> Tuple[List[int], float]:
        t0 = self.clock()
        pend = []
        for r in reqs:
            logits, cache = self._decode(self.params, self._tok[r.rid],
                                         self._caches[r.rid])
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            self._caches[r.rid] = cache
            self._tok[r.rid] = tok
            pend.append(tok)
        if pend:
            jax.block_until_ready(pend)
        cost = max(0.0, self.clock() - t0)
        return [int(t[0]) for t in pend], cost

    def release(self, req) -> None:
        self._caches.pop(req.rid, None)
        self._tok.pop(req.rid, None)
