"""Continuous-batching serve engine with SLO-aware serving goodput.

The paper's Fig. 15 shows serving Runtime Goodput trailing training
because of fluctuating demand and batch bubbles.  The legacy serve loop
(``repro.launch.serve.Server``) *creates* those losses by construction:
fixed ``range(0, len(reqs), batch)`` groups, head-of-line blocking while
a group assembles, and every request riding out ``max(r.max_new)`` of
its batch.  This engine schedules around them:

  * **prefill/decode phase split** — admission prefills new requests as
    their own op; decode iterations run over whatever is live;
  * **continuous batching** — per-iteration admission from the request
    queue; finished requests detach immediately and their slot readmits;
  * **paged KV cache** (:class:`repro.serve.kv_cache.PagedKVCache`) —
    admission is gated on block-table space, decode grows block-by-block,
    and block exhaustion preempts the youngest request (recompute
    preemption, booked as a scheduling-layer LOST);
  * **SLO-aware accounting** — decode time for a token delivered past its
    latency deadline is emitted as ``Phase.SLO_BREACH`` (a scheduling-
    layer loss, MAD-Max's batching/parallelism trade-off made visible in
    the attribution waterfall), so ``STEP`` chip-time *is* the
    within-SLO productive time and

        SLO-goodput = within-SLO decode chip-time / capacity chip-time.

Accounting model: each of the engine's ``n_slots`` batch slots is a
chip.  Queue wait is QUEUED (demand-side), prefill is INIT, on-time
decode is STEP, late decode is SLO_BREACH, preempted work is LOST, and
any slot-second not covered by an op is IDLE (the batch bubble) — so the
emitted intervals partition ``n_slots x [t_start, t_end]`` exactly (the
gap/overlap-free tiling property test).

The engine runs in *virtual time*: every executor op returns its cost
and the engine advances its clock by it.  With the simulated executor
the whole run is deterministic bit-for-bit; with the per-slot JAX
executor costs are measured off an injectable clock (the same
``TickClock`` contract the legacy server uses).

``run_static`` is the equal-capacity reference: the legacy fixed-group
policy replayed through the identical executor, SLO, and accounting —
the A/B behind ``BENCH_serve.json``.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.attribution import _SHIFT, _exact
from repro.core.goodput import Layer, Phase
from repro.core.ledger import GoodputLedger
from repro.serve.kv_cache import OutOfBlocksError, PagedKVCache

try:
    import numpy as _np
except ModuleNotFoundError:          # pure-python percentile fallback
    _np = None


# ---------------------------------------------------------------------------
# requests and SLOs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeRequest:
    """One inference request in the engine's virtual timeline."""
    rid: int
    prompt_len: int
    max_new: int                      # total tokens incl. the prefill token
    t_submit: float = 0.0
    pg: float = 1.0                   # program goodput of the serving program
    prompt: Optional[object] = None   # token array, only the JAX executor

    # runtime state (engine-owned)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    token_times: List[float] = dataclasses.field(default_factory=list)
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    preemptions: int = 0
    _runs: List[List] = dataclasses.field(default_factory=list)
    # queue-wait accounting restarts here after a preemption, so the span
    # [submit, first admission) is never emitted twice
    _queued_from: Optional[float] = None

    def _add_run(self, phase: Phase, t0: float, t1: float) -> None:
        """Append a [t0, t1) span, coalescing contiguous same-phase runs."""
        if self._runs and self._runs[-1][0] is phase \
                and self._runs[-1][2] == t0:
            self._runs[-1][2] = t1
        else:
            self._runs.append([phase, t0, t1])


@dataclasses.dataclass(frozen=True)
class ServeSLO:
    """Latency SLO: token ``k`` of a request is on time iff it is
    delivered by ``t_submit + ttft + k * tpot`` (k = 0 is the prefill
    token, so its deadline is the time-to-first-token target)."""
    ttft: float = math.inf            # time-to-first-token target (s)
    tpot: float = math.inf            # per-output-token target (s)

    def deadline(self, req: ServeRequest, k: int) -> float:
        return req.t_submit + self.ttft + k * self.tpot


NO_SLO = ServeSLO()


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------

class SimulatedExecutor:
    """Analytic cost model standing in for the compiled program: batching
    amortizes a fixed per-op launch cost over the active slots, which is
    exactly the economy continuous batching exists to exploit.

      prefill cost = prefill_fixed + Σ prompt_len * prefill_per_token
      decode cost  = decode_fixed + n_active * decode_per_token

    Tokens are a deterministic function of (rid, position) so same-seed
    runs are bit-for-bit identical with no model in the loop — the serve
    analog of the fleet simulator.
    """

    def __init__(self, prefill_fixed: float = 5e-3,
                 prefill_per_token: float = 5e-5,
                 decode_fixed: float = 8e-3,
                 decode_per_token: float = 1e-3,
                 vocab_size: int = 50_000):
        self.prefill_fixed = prefill_fixed
        self.prefill_per_token = prefill_per_token
        self.decode_fixed = decode_fixed
        self.decode_per_token = decode_per_token
        self.vocab_size = vocab_size

    def _token(self, req: ServeRequest, k: int) -> int:
        return (req.rid * 7919 + k * 31 + 17) % self.vocab_size

    def prefill(self, reqs: Sequence[ServeRequest]) -> Tuple[List[int], float]:
        cost = self.prefill_fixed + sum(
            r.prompt_len * self.prefill_per_token for r in reqs)
        return [self._token(r, 0) for r in reqs], cost

    def decode(self, reqs: Sequence[ServeRequest]) -> Tuple[List[int], float]:
        cost = self.decode_fixed + len(reqs) * self.decode_per_token
        return [self._token(r, len(r.out_tokens)) for r in reqs], cost

    def release(self, req: ServeRequest) -> None:
        pass


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------

def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    if _np is not None:
        return float(_np.percentile(_np.asarray(xs, dtype=_np.float64), q))
    ys = sorted(xs)
    pos = (len(ys) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(ys) - 1)
    return ys[lo] + (ys[hi] - ys[lo]) * (pos - lo)


@dataclasses.dataclass
class ServeReport:
    """Serving metrics + goodput for one engine run (JSON-ready)."""
    engine: str
    n_slots: int
    requests: int
    tokens: int
    tokens_within_slo: int
    slo_token_goodput: float          # on-time tokens / tokens
    slo_goodput: float                # within-SLO STEP chip-time / capacity
    preemptions: int
    span: float
    capacity_chip_time: float
    goodput: Dict[str, float]         # SG/RG/PG/MPG from the shared ledger
    ttft_s: Dict[str, float]          # mean / p50 / p99
    tpot_s: Dict[str, float]          # mean / p50 / p99
    rg_breakdown: Dict[str, float]
    kv_cache: Optional[Dict[str, int]] = None

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def _latency_stats(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"mean": 0.0, "p50": 0.0, "p99": 0.0}
    return {"mean": sum(xs) / len(xs),
            "p50": _percentile(xs, 50.0),
            "p99": _percentile(xs, 99.0)}


# ---------------------------------------------------------------------------
# the continuous-batching engine
# ---------------------------------------------------------------------------

class ContinuousServeEngine:
    """Per-iteration admission, immediate detach, paged KV, SLO tagging.

    Parameters
    ----------
    n_slots:
        Batch width of the serving replica — the engine's chip count.
    executor:
        Object with ``prefill(reqs) -> (tokens, cost)``,
        ``decode(reqs) -> (tokens, cost)`` and ``release(req)``.
    kv_cache:
        A :class:`PagedKVCache`; defaults to one sized so every slot can
        hold a full ``prompt + max_new`` sequence (no preemption unless
        the caller under-provisions on purpose).
    """

    def __init__(self, n_slots: int, executor,
                 slo: ServeSLO = NO_SLO,
                 kv_cache: Optional[PagedKVCache] = None,
                 ledger: Optional[GoodputLedger] = None,
                 arch: str = "sim"):
        if n_slots <= 0:
            raise ValueError(f"n_slots must be positive, got {n_slots}")
        self.n_slots = n_slots
        self.executor = executor
        self.slo = slo
        self.kv = kv_cache
        self.ledger = ledger if ledger is not None else GoodputLedger()
        self.arch = arch
        # interned segment dicts: one per (phase-role, layer) — the
        # ledger's columnar ingest resolves each only once
        self._segs = {
            name: {"phase_kind": "serve", "arch": arch, "emitter": "serve",
                   "layer": layer.value}
            for name, layer in (
                ("queued", Layer.SCHEDULING), ("init", Layer.MODEL),
                ("step", Layer.MODEL), ("breach", Layer.SCHEDULING),
                ("idle", Layer.SCHEDULING), ("lost", Layer.SCHEDULING))}
        self.t = 0.0
        self.preemptions = 0
        self._idle_run: Optional[List] = None      # [t0, t1, width]
        self._t_start = 0.0
        # exact mirror of the supply-side chip-time this engine emits, as
        # an integer scaled by 2**1074 (every finite float is a multiple
        # of 2**-1074): the intervals tile n_slots x span by construction,
        # so the engine's capacity IS this sum — but n_slots * span can
        # land ulps *below* it under re-associated float addition, which
        # would fail the attribution waterfall's exact
        # capacity-covers-allocated check.  _report rounds this mirror up
        # to the nearest float.  The float twin accumulates the same
        # values in the same order as the ledger's own allocated total,
        # so on a dedicated ledger SG is exactly 1.0 (float summation
        # drift can push the ledger's float total above the rounded-up
        # exact sum).
        self._supply_exact = 0
        self._supply_float = 0.0

    # ---- accounting helpers ----------------------------------------------
    def _advance(self, cost: float, busy: int) -> Tuple[float, float]:
        """Advance virtual time by ``cost``; slot-chips not covered by the
        op are booked into the coalesced engine IDLE run."""
        t0 = self.t
        t1 = t0 + cost
        self.t = t1
        width = self.n_slots - busy
        run = self._idle_run
        if run is not None and run[2] == width and run[1] == t0:
            run[1] = t1
        else:
            self._flush_idle()
            if width > 0:
                self._idle_run = [t0, t1, width]
        return t0, t1

    def _flush_idle(self) -> None:
        run, self._idle_run = self._idle_run, None
        if run is not None and run[1] > run[0]:
            self._supply_exact += _exact((run[1] - run[0]) * run[2])
            self._supply_float += (run[1] - run[0]) * run[2]
            self.ledger.emit(job_id="bubble", phase=Phase.IDLE,
                             t0=run[0], t1=run[1], chips=run[2],
                             segment=self._segs["idle"])

    def _flush_request(self, r: ServeRequest) -> None:
        """Columnar-ingest a detached request's QUEUED span + run list."""
        segs = self._segs
        job_ids, phases, t0s, t1s, chips, pgs, seg_col = \
            [], [], [], [], [], [], []

        def row(phase, a, b, seg, pg=1.0):
            job_ids.append(f"req{r.rid}")
            phases.append(phase)
            t0s.append(a)
            t1s.append(b)
            chips.append(1)
            pgs.append(pg)
            seg_col.append(seg)

        queued_from = (r.t_submit if r._queued_from is None
                       else r._queued_from)
        if r.t_admit > queued_from:
            row(Phase.QUEUED, queued_from, r.t_admit, segs["queued"])
        seg_of = {Phase.STEP: segs["step"],
                  Phase.SLO_BREACH: segs["breach"],
                  Phase.LOST: segs["lost"],
                  Phase.INIT: segs["init"],
                  Phase.IDLE: segs["idle"]}
        for phase, a, b in r._runs:
            if b > a:
                self._supply_exact += _exact((b - a) * 1)
                self._supply_float += (b - a) * 1
            row(phase, a, b, seg_of[phase],
                pg=r.pg if phase is Phase.STEP else 1.0)
        r._runs = []
        self.ledger.add_intervals(job_ids, phases, t0s, t1s, chips, pgs,
                                  seg_col)

    # ---- the run loop -----------------------------------------------------
    def run(self, requests: Sequence[ServeRequest]) -> ServeReport:
        reqs = sorted(requests, key=lambda r: (r.t_submit, r.rid))
        if self.kv is None:
            need = max((r.prompt_len + r.max_new for r in reqs), default=1)
            self.kv = PagedKVCache(
                n_blocks=self.n_slots * max(
                    1, -(-need // 128)), block_tokens=128)
        kv = self.kv
        for r in reqs:
            if r.max_new < 1 or r.prompt_len < 1:
                raise ValueError(
                    f"request {r.rid}: prompt_len and max_new must be >= 1")
            if kv.blocks_needed(r.prompt_len + r.max_new) > kv.n_blocks:
                raise ValueError(
                    f"request {r.rid} needs "
                    f"{kv.blocks_needed(r.prompt_len + r.max_new)} KV "
                    f"blocks but the cache holds {kv.n_blocks}")
        queue = deque(reqs)
        active: List[ServeRequest] = []
        done: List[ServeRequest] = []
        self.t = self._t_start = queue[0].t_submit if queue else 0.0
        self.preemptions = 0

        while queue or active:
            # 1) admission: drain arrived requests into free slots, gated
            #    on the paged cache fitting their full sequence right now
            admitted: List[ServeRequest] = []
            while queue and len(active) + len(admitted) < self.n_slots:
                nxt = queue[0]
                if nxt.t_submit > self.t:
                    if active or admitted:
                        break
                    # engine idle: jump to the next arrival
                    self._advance(nxt.t_submit - self.t, busy=0)
                    continue
                if not kv.can_allocate(nxt.prompt_len + nxt.max_new):
                    break             # wait for detaches to free blocks
                queue.popleft()
                kv.allocate(nxt.rid, nxt.prompt_len)
                nxt.t_admit = self.t
                admitted.append(nxt)

            # 2) prefill phase: one op for this iteration's admissions
            if admitted:
                toks, cost = self.executor.prefill(admitted)
                t0, t1 = self._advance(cost, busy=len(admitted))
                for r, tok in zip(admitted, toks):
                    r.out_tokens.append(tok)
                    r.token_times.append(t1)
                    r.t_first = t1
                    r._add_run(Phase.INIT, t0, t1)
                    if r.max_new == 1:
                        self._detach(r, done)
                    else:
                        active.append(r)
                continue              # re-check admission before decoding

            if not active:
                continue

            # 3) KV growth for this decode iteration; exhaustion preempts
            #    the youngest other request (recompute preemption)
            survivors: List[ServeRequest] = []
            for r in list(active):
                if r not in active:
                    continue          # preempted by an earlier grower
                while True:
                    try:
                        kv.append_token(r.rid)
                        survivors.append(r)
                        break
                    except OutOfBlocksError:
                        victim = self._pick_victim(active, exclude=r)
                        assert victim is not None, \
                            "sole request cannot exhaust a validated cache"
                        self._preempt(victim, active, survivors, queue)

            # 4) decode one iteration for the survivors
            toks, cost = self.executor.decode(survivors)
            t0, t1 = self._advance(cost, busy=len(survivors))
            for r, tok in zip(survivors, toks):
                k = len(r.out_tokens)          # 0-based output-token index
                r.out_tokens.append(tok)
                r.token_times.append(t1)
                on_time = t1 <= self.slo.deadline(r, k)
                r._add_run(Phase.STEP if on_time else Phase.SLO_BREACH,
                           t0, t1)
                if len(r.out_tokens) >= r.max_new:
                    active.remove(r)
                    self._detach(r, done)

        self._flush_idle()
        return self._report(done, engine="continuous")

    def _pick_victim(self, active: List[ServeRequest],
                     exclude: ServeRequest) -> Optional[ServeRequest]:
        cands = [r for r in active if r is not exclude]
        if not cands:
            return None
        return max(cands, key=lambda r: (r.t_admit, r.rid))

    def _preempt(self, victim: ServeRequest, active: List[ServeRequest],
                 survivors: List[ServeRequest],
                 queue: deque) -> None:
        """Recompute preemption: the victim's resident work is rolled back
        (its INIT/STEP/SLO_BREACH runs re-emit as scheduling-layer LOST),
        its blocks free, and it re-queues for a fresh admission."""
        self.kv.free(victim.rid)
        self.executor.release(victim)
        victim._runs = [[Phase.LOST, a, b] for _, a, b in victim._runs]
        self._flush_request(victim)
        victim.out_tokens = []
        victim.token_times = []
        victim.t_first = 0.0
        victim._queued_from = self.t
        victim.preemptions += 1
        self.preemptions += 1
        active.remove(victim)
        if victim in survivors:
            survivors.remove(victim)
        # re-admission keeps arrival order among the waiting
        queue.appendleft(victim)

    def _detach(self, r: ServeRequest, done: List[ServeRequest]) -> None:
        r.t_done = self.t
        self.kv.free(r.rid)
        self.executor.release(r)
        self._flush_request(r)
        done.append(r)

    def _report(self, done: List[ServeRequest], engine: str) -> ServeReport:
        span = max(0.0, self.t - self._t_start)
        # mathematically n_slots * span — see _supply_exact for why the
        # capacity comes from the emitted-interval mirror, rounded up to
        # the nearest float so it covers the exact allocated sum
        from fractions import Fraction

        frac = Fraction(self._supply_exact, 1 << _SHIFT)
        capacity = float(frac)
        if Fraction(capacity) < frac:
            capacity = math.nextafter(capacity, math.inf)
        capacity = max(capacity, self._supply_float)
        self.ledger.add_capacity(capacity)
        rep = self.ledger.report()
        tokens = sum(len(r.out_tokens) for r in done)
        within = sum(
            1 for r in done for k, tt in enumerate(r.token_times)
            if tt <= self.slo.deadline(r, k))
        ttfts = [r.t_first - r.t_submit for r in done if r.t_first]
        tpots = [(r.t_done - r.t_first) / (len(r.out_tokens) - 1)
                 for r in done if len(r.out_tokens) > 1]
        return ServeReport(
            engine=engine,
            n_slots=self.n_slots,
            requests=len(done),
            tokens=tokens,
            tokens_within_slo=within,
            slo_token_goodput=within / tokens if tokens else 0.0,
            slo_goodput=(rep.productive_chip_time / capacity
                         if capacity else 0.0),
            preemptions=self.preemptions,
            span=span,
            capacity_chip_time=capacity,
            goodput=rep.as_dict(),
            ttft_s=_latency_stats(ttfts),
            tpot_s=_latency_stats(tpots),
            rg_breakdown=self.ledger.rg_breakdown(),
            kv_cache=self.kv.stats.as_dict() if self.kv else None,
        )


# ---------------------------------------------------------------------------
# the static reference (equal-capacity A/B baseline)
# ---------------------------------------------------------------------------

def run_static(requests: Sequence[ServeRequest], batch: int, executor,
               slo: ServeSLO = NO_SLO,
               ledger: Optional[GoodputLedger] = None,
               arch: str = "sim") -> ServeReport:
    """The legacy fixed-group policy under the engine's accounting: groups
    of ``batch`` requests in submission order, each group waiting for its
    last member (head-of-line blocking), prefilled together, and decoded
    ``max(r.max_new)`` iterations at full compiled width — finished
    requests ride the batch out as IDLE, tail groups pad with IDLE slots.
    Identical executor, SLO, and emission shapes as the continuous
    engine, so the two reports differ only by scheduling policy.
    """
    eng = ContinuousServeEngine(batch, executor, slo=slo, ledger=ledger,
                                arch=arch)
    ledger = eng.ledger
    reqs = sorted(requests, key=lambda r: (r.t_submit, r.rid))
    eng.t = eng._t_start = reqs[0].t_submit if reqs else 0.0
    done: List[ServeRequest] = []
    for g0 in range(0, len(reqs), batch):
        group = reqs[g0:g0 + batch]
        start = max(eng.t, max(r.t_submit for r in group))
        if start > eng.t:             # whole replica waits for the group
            eng._advance(start - eng.t, busy=0)
        for r in group:
            r.t_admit = eng.t
        toks, cost = executor.prefill(group)
        t0, t1 = eng._advance(cost, busy=len(group))
        for r, tok in zip(group, toks):
            r.out_tokens.append(tok)
            r.token_times.append(t1)
            r.t_first = t1
            r._add_run(Phase.INIT, t0, t1)
        live = [r for r in group if r.max_new > 1]
        for _ in range(max(r.max_new for r in group) - 1):
            # the compiled program runs at full group width regardless of
            # how many slots still need tokens — the static bubble
            dtoks, cost = executor.decode(group)
            t0, t1 = eng._advance(cost, busy=len(group))
            for r, tok in zip(group, dtoks):
                if len(r.out_tokens) < r.max_new:
                    k = len(r.out_tokens)
                    r.out_tokens.append(tok)
                    r.token_times.append(t1)
                    on_time = t1 <= slo.deadline(r, k)
                    r._add_run(Phase.STEP if on_time else Phase.SLO_BREACH,
                               t0, t1)
                else:                 # riding out the longest request
                    r._add_run(Phase.IDLE, t0, t1)
        for r in group:
            r.t_done = r.token_times[-1]
            executor.release(r)
            eng._flush_request(r)
            done.append(r)
    eng._flush_idle()
    report = eng._report(done, engine="static")
    report.kv_cache = None            # dense per-slot reservation, unpaged
    return report


# ---------------------------------------------------------------------------
# synthetic request workloads (scenario-arrival driven)
# ---------------------------------------------------------------------------

def synthetic_requests(arrivals: Sequence[float], prompt_len: int = 128,
                       max_new: Tuple[int, int] = (16, 64),
                       seed: int = 0, pg: float = 1.0,
                       prompt_maker: Optional[Callable] = None
                       ) -> List[ServeRequest]:
    """Requests over the given arrival times (see
    ``repro.fleet.scenarios.request_arrivals``) with per-request output
    lengths drawn from a seeded stream — hermetic like the fleet
    workloads."""
    import random as _random

    rng = _random.Random(seed)
    lo, hi = max_new
    out = []
    for i, t in enumerate(arrivals):
        out.append(ServeRequest(
            rid=i, prompt_len=prompt_len, max_new=rng.randint(lo, hi),
            t_submit=float(t), pg=pg,
            prompt=prompt_maker(i) if prompt_maker is not None else None))
    return out
