"""Paged KV-cache allocator for the continuous-batching serve engine.

vLLM-style block-table memory management: the decode KV cache is carved
into fixed-size blocks of ``block_tokens`` token slots, and each live
request holds a *block table* — an ordered list of block ids its tokens
occupy.  Admission needs only enough free blocks for the prompt; decode
grows a request one block at a time as generation crosses block
boundaries, so memory tracks *actual* sequence lengths instead of the
worst-case ``prompt + max_new`` a dense per-slot cache must reserve.

``block_tokens`` defaults to 128 — the MXU-aligned ``block_k`` tile of
the Pallas flash-attention kernel
(``repro.kernels.flash_attention``): a paged attention kernel consumes
the KV cache one (block_k, head_dim) VMEM tile per grid step, so sizing
allocator blocks to the kernel's kv tile means a block table maps 1:1
onto kernel grid iterations with no partial-tile waste.

Everything is deterministic: the free list is a LIFO stack, so the same
admission/free sequence always yields the same block tables (the serve
trace record/replay contract extends down to memory layout).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


# block_k of repro.kernels.flash_attention.flash_attention_bshd — keep in
# sync (test_serve_engine pins this against the kernel's default).
FLASH_ATTENTION_BLOCK_K = 128


class OutOfBlocksError(RuntimeError):
    """Raised when an allocation cannot be satisfied; the engine responds
    by preempting a victim request (recompute preemption)."""


@dataclasses.dataclass
class KVCacheStats:
    """Cumulative allocator telemetry (reported into serve artifacts)."""
    n_blocks: int = 0
    block_tokens: int = 0
    peak_blocks_used: int = 0
    allocations: int = 0
    block_appends: int = 0
    frees: int = 0
    failed_allocations: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class PagedKVCache:
    """Block-granular KV-cache bookkeeping for one serving replica.

    This is the *allocator*: it owns which token positions live in which
    block, not the tensors themselves.  The executor backing real model
    state maps (request, block table) onto its storage; the simulated
    executor needs only the occupancy accounting.
    """

    def __init__(self, n_blocks: int,
                 block_tokens: int = FLASH_ATTENTION_BLOCK_K):
        if n_blocks <= 0:
            raise ValueError(f"n_blocks must be positive, got {n_blocks}")
        if block_tokens <= 0:
            raise ValueError(
                f"block_tokens must be positive, got {block_tokens}")
        self.n_blocks = n_blocks
        self.block_tokens = block_tokens
        # LIFO free stack: pop from the end -> block 0 first
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}
        self._tokens: Dict[int, int] = {}
        self.stats = KVCacheStats(n_blocks=n_blocks,
                                  block_tokens=block_tokens)

    # ---- queries ----------------------------------------------------------
    def blocks_needed(self, n_tokens: int) -> int:
        """ceil(n_tokens / block_tokens) — full blocks covering a span."""
        return -(-max(0, n_tokens) // self.block_tokens)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.blocks_needed(n_tokens) <= len(self._free)

    def block_table(self, rid: int) -> List[int]:
        return list(self._tables[rid])

    def seq_len(self, rid: int) -> int:
        return self._tokens[rid]

    def utilization(self) -> float:
        return self.used_blocks / self.n_blocks

    # ---- mutation ---------------------------------------------------------
    def allocate(self, rid: int, n_tokens: int) -> List[int]:
        """Claim blocks for a request's first ``n_tokens`` (its prompt)."""
        if rid in self._tables:
            raise ValueError(f"request {rid} already holds a block table")
        if n_tokens <= 0:
            raise ValueError(
                f"n_tokens must be positive, got {n_tokens}")
        need = self.blocks_needed(n_tokens)
        if need > len(self._free):
            self.stats.failed_allocations += 1
            raise OutOfBlocksError(
                f"need {need} blocks for {n_tokens} tokens, "
                f"{len(self._free)} free")
        blocks = [self._free.pop() for _ in range(need)]
        self._tables[rid] = blocks
        self._tokens[rid] = n_tokens
        self.stats.allocations += 1
        self.stats.peak_blocks_used = max(self.stats.peak_blocks_used,
                                          self.used_blocks)
        return list(blocks)

    def append_token(self, rid: int) -> bool:
        """Grow a request by one generated token.

        Returns True when the append claimed a fresh block (the token
        crossed a block boundary).  Raises :class:`OutOfBlocksError` when
        a fresh block is needed but none is free — the engine's cue to
        preempt a victim.
        """
        if rid not in self._tables:
            raise KeyError(f"request {rid} holds no block table")
        n = self._tokens[rid]
        if n % self.block_tokens == 0:       # the current blocks are full
            if not self._free:
                self.stats.failed_allocations += 1
                raise OutOfBlocksError(
                    f"request {rid} needs a decode block, 0 free")
            self._tables[rid].append(self._free.pop())
            self._tokens[rid] = n + 1
            self.stats.block_appends += 1
            self.stats.peak_blocks_used = max(self.stats.peak_blocks_used,
                                              self.used_blocks)
            return True
        self._tokens[rid] = n + 1
        return False

    def free(self, rid: int) -> int:
        """Release a request's blocks (detach or preemption); returns the
        number of blocks returned to the free stack."""
        blocks = self._tables.pop(rid)
        del self._tokens[rid]
        # LIFO reuse in reverse claim order keeps the free stack a
        # deterministic function of the event sequence
        self._free.extend(reversed(blocks))
        self.stats.frees += 1
        return len(blocks)
