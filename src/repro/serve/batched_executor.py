"""Batched real-model executor: one jitted decode at fixed width over the
paged KV pool, zero recompilation across admission/detach.

Where :class:`repro.serve.jax_executor.JaxSlotExecutor` runs per-slot
batch-1 decode (N kernel launches per iteration, no MXU batching), this
executor owns block-table-backed KV storage shared with the engine's
:class:`repro.serve.kv_cache.PagedKVCache` allocator and decodes every
live slot in ONE jitted call:

  * **fixed batch width** — the decode function is jitted once at
    ``n_slots`` rows; a live request is a *row assignment*, admission
    pops a free row, detach pushes it back.  Inactive rows carry
    ``length == 0`` and an all-null block table, so they mask out inside
    the paged-attention kernel instead of changing any shape;
  * **block-table ABI** — the engine allocates/grows/frees block tables
    on ``self.kv``; before each decode the executor re-reads the live
    tables and sequence lengths into its fixed (W, nb_max) host arrays,
    so allocator state IS the kernel's gather map (one extra *null* page
    backs inactive rows' writes);
  * **prefill reuse** — prompts run through the same batch-1 jitted
    prefill as the per-slot executor (bitwise-identical first token),
    then the collected cache scatters into this request's pages.

Construct the engine with ``kv_cache=executor.kv`` — the allocator must
be shared or the gather map and the bookkeeping drift apart.

MoE configs decode with ``capacity_factor`` raised to ``num_experts``
(drop-free routing): at fixed width W a garbage inactive row must never
evict an active token from an expert buffer, and a capacity that admits
every assignment makes each row's expert output independent of its
batch neighbours — the token-identity-vs-per-slot property the tests
pin.

``encdec``/``vlm``/``hybrid``/``ssm`` families resist paging (encoder
context / recurrent state outside the block tables); ``make_executor``
falls back to the per-slot executor for them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model, transformer
from repro.serve.kv_cache import FLASH_ATTENTION_BLOCK_K, PagedKVCache


class JaxBatchedExecutor:
    """Fixed-width batched paged decode for the continuous engine."""

    def __init__(self, cfg, max_len: int, n_slots: int,
                 clock: Callable[[], float] = time.monotonic,
                 attn_impl: str = "auto", interpret: bool = False):
        if not model.supports_paged_decode(cfg, max_len):
            raise ValueError(
                f"family {cfg.family!r} (window={cfg.attention_window}) "
                f"does not support paged decode; use JaxSlotExecutor")
        self.cfg = cfg
        self.max_len = max_len
        self.n_slots = n_slots
        self.clock = clock
        self.block_tokens = FLASH_ATTENTION_BLOCK_K
        self.nb_max = -(-max_len // self.block_tokens)
        n_blocks = n_slots * self.nb_max
        # the allocator the engine must share (kv_cache=executor.kv)
        self.kv = PagedKVCache(n_blocks, self.block_tokens)
        self.null_page = n_blocks          # pool holds n_blocks + 1 pages
        shape = transformer.paged_kv_shape(cfg, n_blocks + 1,
                                           self.block_tokens)
        self._kp = jnp.zeros(shape, cfg.compute_dtype)
        self._vp = jnp.zeros(shape, cfg.compute_dtype)

        self.params = model.init_params(cfg, jax.random.key(0))
        # decode-time MoE capacity admits every assignment (see module doc)
        cfg_dec = cfg
        if cfg.num_experts > 0:
            cfg_dec = dataclasses.replace(
                cfg, capacity_factor=max(cfg.capacity_factor,
                                         float(cfg.num_experts)))
        self._prefill = jax.jit(
            lambda p, b: transformer.prefill(p, b, cfg, max_len=max_len))
        self._scatter = jax.jit(
            lambda c, kp, vp, pg, off: transformer.scatter_prefill_pages(
                c, cfg, kp, vp, pg, off),
            donate_argnums=(1, 2))

        step = model.paged_decode_fn(cfg_dec, attn_impl=attn_impl,
                                     interpret=interpret)

        def _step(p, tok, lens, kp, vp, bt):
            logits, kp, vp = step(p, tok, lens, kp, vp, bt)
            return jnp.argmax(logits, -1).astype(jnp.int32), kp, vp

        # the ONE decode compile: fixed (W,)/(W, nb_max) shapes forever
        self._decode = jax.jit(_step, donate_argnums=(3, 4))

        # host-side row state (fixed width W)
        self.rows: Dict[int, int] = {}              # rid -> row
        self._free_rows: List[int] = list(range(n_slots - 1, -1, -1))
        self._tok = np.zeros((n_slots,), np.int32)
        self._len = np.zeros((n_slots,), np.int32)
        self._tables = np.full((n_slots, self.nb_max), self.null_page,
                               np.int32)

    # ---- introspection ----------------------------------------------------
    def decode_compiles(self) -> int:
        """Compile count of the batched decode (the zero-recompile probe)."""
        return self._decode._cache_size()

    # ---- executor protocol ------------------------------------------------
    def _batch1(self, req):
        if req.prompt is None:
            raise ValueError(f"request {req.rid} carries no prompt tokens")
        return {"tokens": jnp.asarray(np.asarray(req.prompt)[None, :])}

    def prefill(self, reqs: Sequence) -> Tuple[List[int], float]:
        t0 = self.clock()
        pend = []
        for r in reqs:
            row = self._free_rows.pop()
            self.rows[r.rid] = row
            logits, cache = self._prefill(self.params, self._batch1(r))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            table = self.kv.block_table(r.rid)     # engine allocated first
            s = int(np.asarray(r.prompt).shape[-1])
            pos = np.arange(s)
            page_ids = jnp.asarray(np.asarray(table, np.int32)
                                   [pos // self.block_tokens])
            offs = jnp.asarray((pos % self.block_tokens).astype(np.int32))
            self._kp, self._vp = self._scatter(cache, self._kp, self._vp,
                                               page_ids, offs)
            self._len[row] = s
            pend.append((r, row, tok))
        if pend:
            jax.block_until_ready([t for _, _, t in pend])
        cost = max(0.0, self.clock() - t0)
        toks = []
        for r, row, tok in pend:
            t = int(tok[0])
            self._tok[row] = t
            toks.append(t)
        return toks, cost

    def decode(self, reqs: Sequence) -> Tuple[List[int], float]:
        t0 = self.clock()
        # refresh the gather map from the allocator (the engine's
        # append_token may have claimed fresh blocks since last step)
        for r in reqs:
            row = self.rows[r.rid]
            self._len[row] = self.kv.seq_len(r.rid)
            table = self.kv.block_table(r.rid)
            self._tables[row, :len(table)] = table
        tok, self._kp, self._vp = self._decode(
            self.params, jnp.asarray(self._tok), jnp.asarray(self._len),
            self._kp, self._vp, jnp.asarray(self._tables))
        tok_np = np.asarray(jax.block_until_ready(tok))
        cost = max(0.0, self.clock() - t0)
        self._tok = tok_np.copy()
        return [int(tok_np[self.rows[r.rid]]) for r in reqs], cost

    def release(self, req) -> None:
        row = self.rows.pop(req.rid, None)
        if row is None:
            return
        self._free_rows.append(row)
        self._tok[row] = 0
        self._len[row] = 0
        self._tables[row, :] = self.null_page


def make_executor(cfg, max_len: int, n_slots: int,
                  clock: Callable[[], float] = time.monotonic,
                  attn_impl: str = "auto", interpret: bool = False):
    """Batched paged executor when the family supports it, else the
    per-slot fallback.  Returns (executor, kv_cache-or-None): pass the
    kv cache (the batched executor's own allocator) to the engine."""
    if model.supports_paged_decode(cfg, max_len):
        ex = JaxBatchedExecutor(cfg, max_len, n_slots, clock=clock,
                                attn_impl=attn_impl, interpret=interpret)
        return ex, ex.kv
    from repro.serve.jax_executor import JaxSlotExecutor

    return JaxSlotExecutor(cfg, max_len, clock=clock), None
