"""Production serve path: continuous batching, paged KV cache, SLO-aware
serving goodput.  (`repro.serve.jax_executor` — the per-slot real-model
executor — and `repro.serve.batched_executor` — the batched paged-decode
executor over the allocator's block tables — are imported lazily by
callers so this package stays importable without JAX, e.g. in the
numpy-only benchmark CI jobs.)"""
from repro.serve.engine import (NO_SLO, ContinuousServeEngine, ServeReport,
                                ServeRequest, ServeSLO, SimulatedExecutor,
                                run_static, synthetic_requests)
from repro.serve.kv_cache import (FLASH_ATTENTION_BLOCK_K, KVCacheStats,
                                  OutOfBlocksError, PagedKVCache)

__all__ = [
    "NO_SLO", "ContinuousServeEngine", "ServeReport", "ServeRequest",
    "ServeSLO", "SimulatedExecutor", "run_static", "synthetic_requests",
    "FLASH_ATTENTION_BLOCK_K", "KVCacheStats", "OutOfBlocksError",
    "PagedKVCache",
]
