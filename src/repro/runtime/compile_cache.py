"""Ahead-of-time compilation cache (paper §5.2: "compile on cheap hardware,
store, and skip JIT on the accelerators").

Two layers:
  * jax's persistent compilation cache (XLA executable serialization) —
    enabled per-process against a shared directory;
  * an in-process AOT registry keyed by (arch, shape, mesh, donation
    signature) holding `Lowered`/`Compiled` objects so repeated launches
    within one controller reuse executables.

`CompileClock` records compile wall-time per key; the Runtime-Goodput
benchmark (fig14) uses it to quantify the INIT-time saving of a warm cache.
"""
from __future__ import annotations

import pathlib
import time
from typing import Any, Callable, Dict, Hashable, Tuple

import jax

_CACHE_ENABLED = False


def enable_persistent_cache(directory: str) -> None:
    """Turn on XLA's on-disk executable cache (idempotent)."""
    global _CACHE_ENABLED
    pathlib.Path(directory).mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", directory)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    _CACHE_ENABLED = True


class CompileClock:
    def __init__(self):
        self.events: Dict[Hashable, Dict[str, float]] = {}

    def record(self, key: Hashable, seconds: float, hit: bool):
        self.events[key] = {"seconds": seconds, "hit": float(hit)}

    @property
    def total_compile_s(self) -> float:
        return sum(e["seconds"] for e in self.events.values())


class AotCache:
    """In-process executable registry with compile-time accounting."""

    def __init__(self):
        self._store: Dict[Hashable, Any] = {}
        self.clock = CompileClock()

    def get_or_compile(self, key: Hashable,
                       build: Callable[[], Tuple[Any, tuple]]) -> Any:
        """build() -> (jitted_fn, abstract_args); returns Compiled."""
        if key in self._store:
            self.clock.record(key, 0.0, hit=True)
            return self._store[key]
        t0 = time.monotonic()
        fn, args = build()
        compiled = fn.lower(*args).compile()
        self.clock.record(key, time.monotonic() - t0, hit=False)
        self._store[key] = compiled
        return compiled

    def __contains__(self, key: Hashable) -> bool:
        return key in self._store
