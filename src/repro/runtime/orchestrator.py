"""Training orchestrator: the runtime layer of the stack (paper §3.3),
instrumented so every second of chip time lands in an MPG Interval ledger.

Responsibilities: program setup (AOT cache), data feeding (prefetch
pipeline), stepping, checkpoint creation (sync or async), preemption/
failure recovery (restart resumes from the newest committed checkpoint and
books the rolled-back work as LOST — the paper's RG definition).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.goodput import Interval, Layer, Phase
from repro.core.ledger import GoodputLedger
from repro.data.pipeline import DataPipeline
from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.compile_cache import AotCache


@dataclasses.dataclass
class RunConfig:
    steps: int = 50
    batch: int = 4
    seq: int = 64
    checkpoint_every: int = 10
    async_checkpoint: bool = False
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    preempt_at_step: Optional[int] = None   # simulate a mid-run kill
    # what the kill is: "preemption" books the rollback to the scheduling
    # layer, "hardware" (a chip failure) to the hardware layer — the
    # attribution waterfall must show the loss in the right row
    failure_kind: str = "preemption"
    # stream the checkpoint restore on a worker thread while compile and
    # param-init proceed; the hidden read time is reported in the summary
    async_restore: bool = True
    job_id: str = "job0"
    chips: int = 1

    def __post_init__(self):
        if self.failure_kind not in ("preemption", "hardware"):
            raise ValueError(f"failure_kind must be 'preemption' or "
                             f"'hardware', got {self.failure_kind!r}")


class Orchestrator:
    def __init__(self, cfg: ModelConfig, run: RunConfig,
                 aot: Optional[AotCache] = None,
                 ledger: Optional[GoodputLedger] = None,
                 keep_intervals: bool = True):
        self.cfg = cfg
        self.run_cfg = run
        self.aot = aot or AotCache()
        # accounting streams into a GoodputLedger — pass a shared one to
        # fold this run into fleet-wide MPG alongside sim/serve emitters.
        # keep_intervals=False keeps long attribution runs O(1) memory
        # (ignored for an injected ledger; its retention setting wins).
        self.ledger = ledger if ledger is not None else GoodputLedger(
            retain_intervals=keep_intervals)
        self.ckpt = CheckpointManager(run.ckpt_dir, keep=run.keep,
                                      async_mode=run.async_checkpoint)
        self.state = None
        self.step_times: List[float] = []

    @property
    def intervals(self) -> List[Interval]:
        """The raw event stream (requires a retaining ledger)."""
        if self.ledger.intervals is None:
            raise AttributeError("interval retention is off on this ledger; "
                                 "use the streaming ledger reports instead")
        return self.ledger.intervals

    # ------------------------------------------------------------------
    def _emit(self, phase: Phase, t0: float, t1: float, layer: Layer,
              extra: Optional[Dict[str, str]] = None):
        r = self.run_cfg
        self.ledger.emit(
            job_id=r.job_id, phase=phase, t0=t0, t1=t1, chips=r.chips,
            segment={"arch": self.cfg.name, "phase_kind": "train",
                     "ckpt": "async" if r.async_checkpoint else "sync",
                     "emitter": "runtime", "layer": layer.value,
                     **(extra or {})})

    # ------------------------------------------------------------------
    def _build(self):
        from repro.launch.strategy import make_train_step, abstract_train_state

        cfg, r = self.cfg, self.run_cfg
        step_fn = make_train_step(cfg, AdamWConfig(lr=1e-3))
        from repro.models.config import ShapeConfig

        shape = ShapeConfig("orc", "train", r.seq, r.batch)
        batch_abs = model.input_specs(cfg, shape)

        def build():
            return jax.jit(step_fn, donate_argnums=(0,)), \
                (abstract_train_state(cfg), batch_abs)

        key = (cfg.name, r.batch, r.seq, "train")
        return self.aot.get_or_compile(key, build)

    def _init_state(self):
        from repro.optim import adamw_init

        params = model.init_params(self.cfg, jax.random.key(0))
        return {"params": params, "opt": adamw_init(params)}

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        """Run (or resume) the job; returns summary metrics."""
        r = self.run_cfg
        t_init0 = time.monotonic()
        # async restore: the checkpoint read streams from storage while
        # compile + param-init run; only the non-overlapped remainder
        # extends INIT (the measured reduction lands in the summary)
        restore_fut = self.ckpt.start_restore() if r.async_restore else None
        compile_before = self.aot.clock.total_compile_s
        compiled = self._build()
        # the compile portion of setup is the compiler layer's chip-time;
        # a warm AOT cache records 0s here and the whole INIT shifts to
        # the framework layer — the attribution move fig14 quantifies
        compile_s = self.aot.clock.total_compile_s - compile_before
        t_compiled = t_init0 + compile_s
        example = self._init_state()
        if restore_fut is not None:
            restored, ckpt_step, restore_stats = \
                self.ckpt.finish_restore(restore_fut, example)
        else:
            t_r0 = time.monotonic()
            restored, ckpt_step = self.ckpt.restore(example)
            read_s = time.monotonic() - t_r0
            restore_stats = {"read_s": read_s, "exposed_s": read_s,
                             "overlap_s": 0.0}
        start_step = ckpt_step + 1 if restored is not None else 0
        self.state = restored if restored is not None else example
        pipeline = DataPipeline(self.cfg.vocab_size, r.batch, r.seq,
                                seed=start_step).start()
        t_init1 = time.monotonic()
        if compile_s > 0:
            self._emit(Phase.INIT, t_init0, t_compiled,
                       layer=Layer.COMPILER, extra={"cache": "miss"})
        else:
            t_compiled = t_init0
        self._emit(Phase.INIT, t_compiled, t_init1, layer=Layer.FRAMEWORK,
                   extra={"cache": "hit" if compile_s == 0 else "miss"})

        last_ckpt_step = start_step - 1
        losses = []
        preempted = False
        step = start_step
        try:
            for step in range(start_step, r.steps):
                if r.preempt_at_step is not None and step == r.preempt_at_step:
                    preempted = True
                    break
                batch = next(pipeline)   # wait accounted via pipeline stats
                t1 = time.monotonic()
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                self.state, metrics = compiled(self.state, batch)
                loss = float(metrics["loss"])
                t2 = time.monotonic()
                self._emit(Phase.STEP, t1, t2, layer=Layer.MODEL)
                self.step_times.append(t2 - t1)
                losses.append(loss)
                if (step + 1) % r.checkpoint_every == 0:
                    t3 = time.monotonic()
                    self.ckpt.save(self.state, step)
                    t4 = time.monotonic()
                    self._emit(Phase.CHECKPOINT, t3, t4,
                               layer=Layer.FRAMEWORK)
                    last_ckpt_step = step
        finally:
            pipeline.stop()

        # data-layer stall time from *measured* pipeline stats (Plumber-
        # style, paper §5.2) rather than a per-batch wall-clock heuristic:
        # the consumer-wait total is the chip-time the model spent waiting
        # on input, and the bottleneck stage names the culprit.  Like the
        # LOST rollback below it is a synthetic interval appended after
        # the loop; ``t_cursor`` keeps the two from overlapping (which
        # would over-fill the ledger's time windows).
        t_cursor = time.monotonic()
        pstats = pipeline.analyze()
        if pstats.consumer_wait_s > 0:
            stage, share = pstats.bottleneck()
            self._emit(Phase.DATA_STALL, t_cursor,
                       t_cursor + pstats.consumer_wait_s,
                       layer=Layer.DATA,
                       extra={"stage": stage,
                              "input_bound":
                                  "yes" if pstats.input_bound() else "no"})
            t_cursor += pstats.consumer_wait_s

        if preempted:
            # roll back: work after the last committed checkpoint is LOST
            lost_steps = step - 1 - last_ckpt_step
            if lost_steps > 0 and self.step_times:
                avg = float(np.mean(self.step_times))
                # the rollback's layer follows the kill's cause: a chip
                # failure is a hardware loss, a preemption a scheduling one
                lost_layer = (Layer.HARDWARE if r.failure_kind == "hardware"
                              else Layer.SCHEDULING)
                self._emit(Phase.LOST, t_cursor,
                           t_cursor + lost_steps * avg,
                           layer=lost_layer,
                           extra={"kind": r.failure_kind})
        else:
            self.ckpt.save(self.state, r.steps - 1)
            self.ckpt.wait()
        self.ckpt.wait()

        stage, share = pstats.bottleneck()
        return {
            "start_step": start_step,
            "end_step": step if preempted else r.steps,
            "preempted": preempted,
            "losses": losses,
            "ckpt_metrics": dict(self.ckpt.metrics),
            # restore-overlap accounting: read_s spent streaming from
            # storage, overlap_s of it hidden behind compile/param-init
            # (the INIT-phase reduction), exposed_s the serial remainder
            "restore": dict(restore_stats),
            "compile_s": self.aot.clock.total_compile_s,
            "data": {"bottleneck_stage": stage,
                     "bottleneck_share": share,
                     "input_bound": pstats.input_bound(),
                     "consumer_wait_s": pstats.consumer_wait_s},
        }
