"""Checkpointing: atomic commit protocol + async (double-buffered) writes.

Paper §5.2: synchronous checkpoint writes stall the accelerators (RG loss);
async checkpointing snapshots device state quickly and persists it from a
background thread.  The manager implements:

  * write-tmp -> fsync -> rename -> manifest commit (a torn write can never
    be mistaken for a valid checkpoint — restore reads the manifest only);
  * async mode: device->host snapshot on the caller thread (the only
    device pause), disk serialization on a worker thread;
  * keep-last-k GC, never deleting the newest committed step;
  * restore() returns (state, step) from the newest *readable* committed
    manifest — a corrupted or truncated manifest (or a torn array file
    behind a committed-looking directory) is skipped, falling back to the
    previous committed step instead of raising;
  * start_restore()/finish_restore(): the disk read streams on a worker
    thread so restore overlaps program setup (compile + param init);
  * an optional :class:`FaultInjector` crashes at named protocol points,
    letting tests prove a kill mid-write or mid-restore never surfaces a
    torn checkpoint.

Storage layout:  <dir>/step_<n>/arr_<i>.npy + manifest.json (committed last).
"""
from __future__ import annotations

import json
import pathlib
import shutil
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


class SimulatedCrash(RuntimeError):
    """Raised by a FaultInjector at its configured protocol point."""


class FaultInjector:
    """Deterministic kill switch for checkpoint fault-injection tests.

    ``crash_at`` names a protocol point (``"after_arrays"`` — arrays on
    disk, manifest not yet written; ``"before_commit"`` — manifest in the
    tmp dir, rename pending; ``"mid_restore"`` — manifest parsed, array
    reads pending) and ``skip`` lets the first N hits through, so "kill
    the K-th checkpoint write" is expressible."""

    POINTS = ("after_arrays", "before_commit", "mid_restore")

    def __init__(self, crash_at: str, skip: int = 0):
        if crash_at not in self.POINTS:
            raise ValueError(f"unknown crash point {crash_at!r}; "
                             f"choose from {self.POINTS}")
        self.crash_at = crash_at
        self.skip = skip
        self.hits = 0

    def __call__(self, point: str) -> None:
        if point != self.crash_at:
            return
        self.hits += 1
        if self.hits > self.skip:
            raise SimulatedCrash(f"injected crash at {point}")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_mode: bool = False,
                 fault_injector: Optional[Callable[[str], None]] = None):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_mode = async_mode
        self._fault = fault_injector or (lambda point: None)
        self._pool = ThreadPoolExecutor(max_workers=1) if async_mode else None
        self._pending: Optional[Future] = None
        self.metrics: Dict[str, float] = {
            "device_pause_s": 0.0, "write_s": 0.0, "n_saves": 0}

    # ------------------------------------------------------------------
    def save(self, state: PyTree, step: int) -> None:
        """Checkpoint `state` at `step`; async mode returns immediately
        after the host snapshot (device pause ~ copy time only)."""
        t0 = time.monotonic()
        leaves, treedef = jax.tree.flatten(state)
        host = [np.asarray(x) for x in leaves]      # device -> host snapshot
        pause = time.monotonic() - t0
        self.metrics["device_pause_s"] += pause
        self.metrics["n_saves"] += 1

        if self.async_mode:
            self.wait()                             # one outstanding write
            self._pending = self._pool.submit(self._write, host, step)
        else:
            self._write(host, step)

    def wait(self) -> None:
        """Block until the outstanding async write (if any) is committed."""
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, host: List[np.ndarray], step: int) -> None:
        t0 = time.monotonic()
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f".tmp_step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for i, arr in enumerate(host):
            np.save(tmp / f"arr_{i:05d}.npy", arr, allow_pickle=False)
        self._fault("after_arrays")
        manifest = {"step": step, "n_arrays": len(host),
                    "time": time.time()}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        self._fault("before_commit")
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                           # atomic commit
        self.metrics["write_s"] += time.monotonic() - t0
        self._gc()

    # ------------------------------------------------------------------
    def committed_steps(self) -> List[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                steps.append(int(p.name.split("_")[1]))
        return sorted(steps)

    def _read_step(self, step: int) -> Optional[Tuple[List[np.ndarray], int]]:
        """Host arrays of one committed step, or None when the manifest
        (or an array behind it) is corrupt/truncated — a torn checkpoint
        must fall back, never raise."""
        d = self.dir / f"step_{step:010d}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
            self._fault("mid_restore")
            loaded = [np.load(d / f"arr_{i:05d}.npy", allow_pickle=False)
                      for i in range(int(manifest["n_arrays"]))]
        except SimulatedCrash:
            raise                        # the injected kill, not corruption
        except (OSError, ValueError, KeyError, TypeError):
            return None
        return loaded, step

    def _read_newest(self) -> Optional[Tuple[List[np.ndarray], int]]:
        for step in reversed(self.committed_steps()):
            got = self._read_step(step)
            if got is not None:
                return got
        return None

    @staticmethod
    def _assemble(got: Optional[Tuple[List[np.ndarray], int]],
                  example_state: PyTree) -> Tuple[Optional[PyTree], int]:
        if got is None:
            return None, -1
        loaded, step = got
        leaves, treedef = jax.tree.flatten(example_state)
        assert len(loaded) == len(leaves), "state layout changed"
        restored = [jax.numpy.asarray(a, dtype=l.dtype) if hasattr(l, "dtype")
                    else a for a, l in zip(loaded, leaves)]
        return jax.tree.unflatten(treedef, restored), step

    def restore(self, example_state: PyTree) -> Tuple[Optional[PyTree], int]:
        """Load the newest readable committed checkpoint into
        example_state's structure; returns (state, step) or (None, -1)."""
        return self._assemble(self._read_newest(), example_state)

    # -- streaming restore (overlaps program setup) --------------------
    def start_restore(self) -> Future:
        """Begin reading the newest committed checkpoint from storage on
        a worker thread; the caller overlaps compile/param-init and joins
        via :meth:`finish_restore`."""
        pool = ThreadPoolExecutor(max_workers=1)
        fut = pool.submit(self._timed_read)
        pool.shutdown(wait=False)
        return fut

    def _timed_read(self):
        t0 = time.monotonic()
        got = self._read_newest()
        return got, time.monotonic() - t0

    def finish_restore(self, fut: Future, example_state: PyTree
                       ) -> Tuple[Optional[PyTree], int, Dict[str, float]]:
        """Join a :meth:`start_restore` read and assemble the state.

        The stats dict carries the overlap accounting: ``read_s`` is the
        full storage-read time, ``exposed_s`` how long this join actually
        blocked, ``overlap_s`` the read time hidden behind setup work —
        the measured INIT reduction of the async restore."""
        t0 = time.monotonic()
        got, read_s = fut.result()
        exposed = time.monotonic() - t0
        state, step = self._assemble(got, example_state)
        return state, step, {"read_s": read_s, "exposed_s": exposed,
                             "overlap_s": max(0.0, read_s - exposed)}

    def _gc(self) -> None:
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)
