"""Checkpointing: atomic commit protocol + async (double-buffered) writes.

Paper §5.2: synchronous checkpoint writes stall the accelerators (RG loss);
async checkpointing snapshots device state quickly and persists it from a
background thread.  The manager implements:

  * write-tmp -> fsync -> rename -> manifest commit (a torn write can never
    be mistaken for a valid checkpoint — restore reads the manifest only);
  * async mode: device->host snapshot on the caller thread (the only
    device pause), disk serialization on a worker thread;
  * keep-last-k GC, never deleting the newest committed step;
  * restore() returns (state, step) from the newest committed manifest.

Storage layout:  <dir>/step_<n>/arr_<i>.npy + manifest.json (committed last).
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_mode: bool = False):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_mode = async_mode
        self._pool = ThreadPoolExecutor(max_workers=1) if async_mode else None
        self._pending: Optional[Future] = None
        self.metrics: Dict[str, float] = {
            "device_pause_s": 0.0, "write_s": 0.0, "n_saves": 0}

    # ------------------------------------------------------------------
    def save(self, state: PyTree, step: int) -> None:
        """Checkpoint `state` at `step`; async mode returns immediately
        after the host snapshot (device pause ~ copy time only)."""
        t0 = time.monotonic()
        leaves, treedef = jax.tree.flatten(state)
        host = [np.asarray(x) for x in leaves]      # device -> host snapshot
        pause = time.monotonic() - t0
        self.metrics["device_pause_s"] += pause
        self.metrics["n_saves"] += 1

        if self.async_mode:
            self.wait()                             # one outstanding write
            self._pending = self._pool.submit(self._write, host, step)
        else:
            self._write(host, step)

    def wait(self) -> None:
        """Block until the outstanding async write (if any) is committed."""
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, host: List[np.ndarray], step: int) -> None:
        t0 = time.monotonic()
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f".tmp_step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for i, arr in enumerate(host):
            np.save(tmp / f"arr_{i:05d}.npy", arr, allow_pickle=False)
        manifest = {"step": step, "n_arrays": len(host),
                    "time": time.time()}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                           # atomic commit
        self.metrics["write_s"] += time.monotonic() - t0
        self._gc()

    # ------------------------------------------------------------------
    def committed_steps(self) -> List[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                steps.append(int(p.name.split("_")[1]))
        return sorted(steps)

    def restore(self, example_state: PyTree) -> Tuple[Optional[PyTree], int]:
        """Load the newest committed checkpoint into example_state's
        structure; returns (state, step) or (None, -1)."""
        steps = self.committed_steps()
        if not steps:
            return None, -1
        step = steps[-1]
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = jax.tree.flatten(example_state)
        assert manifest["n_arrays"] == len(leaves), "state layout changed"
        loaded = [np.load(d / f"arr_{i:05d}.npy")
                  for i in range(len(leaves))]
        restored = [jax.numpy.asarray(a, dtype=l.dtype) if hasattr(l, "dtype")
                    else a for a, l in zip(loaded, leaves)]
        return jax.tree.unflatten(treedef, restored), step

    def _gc(self) -> None:
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)
