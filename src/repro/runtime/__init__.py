from repro.runtime.checkpoint import CheckpointManager  # noqa: F401
from repro.runtime.orchestrator import Orchestrator, RunConfig  # noqa: F401
