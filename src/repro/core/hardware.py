"""Target-hardware constants (TPU v5e) for roofline terms and the fleet
simulator's analytical step-time model."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s per chip
    hbm_bw: float               # bytes/s
    hbm_bytes: float            # capacity
    ici_link_bw: float          # bytes/s per link (one direction)
    ici_links: int              # links per chip in a 2D torus


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    hbm_bytes=16 * 1024 ** 3,
    ici_link_bw=50e9,
    ici_links=4,
)

# Older / newer generations the heterogeneous-fleet scenarios mix in
# (paper §3.1: the fleet spans several TPU generations at once; per-chip
# peak FLOPS is what Program Goodput normalizes against).
TPU_V4 = ChipSpec(
    name="tpu-v4",
    peak_flops_bf16=275e12,
    hbm_bw=1228e9,
    hbm_bytes=32 * 1024 ** 3,
    ici_link_bw=50e9,
    ici_links=6,
)

TPU_V5P = ChipSpec(
    name="tpu-v5p",
    peak_flops_bf16=459e12,
    hbm_bw=2765e9,
    hbm_bytes=95 * 1024 ** 3,
    ici_link_bw=100e9,
    ici_links=6,
)

GENERATIONS = {c.name: c for c in (TPU_V4, TPU_V5E, TPU_V5P)}

# Cross-pod (DCN) bandwidth per chip — used by the fleet simulator for
# multi-pod gradient all-reduces (pod axis).
DCN_BW_PER_CHIP = 6.25e9  # bytes/s


def ideal_step_time(model_flops: float, chips: int,
                    chip: ChipSpec = TPU_V5E) -> float:
    """The paper's Program-Goodput numerator: intrinsic FLOPs at peak."""
    return model_flops / (chips * chip.peak_flops_bf16)
