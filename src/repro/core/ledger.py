"""Streaming GoodputLedger: one fleet-wide accounting sink (paper §4-§5).

The paper's central move is a *single* MPG = SG x RG x PG accounting that
spans the whole stack — scheduler, runtime, and program layers.  Before
this module each layer kept its own ``List[Interval]`` and every report
re-walked the full list; a month of fleet time at production job counts
materializes millions of intervals just to produce four numbers.

``GoodputLedger`` is an append-only event sink with O(1)-per-event
incremental accumulators:

  * aggregate allocated / productive / ideal chip-time (the MPG inputs);
  * per-phase chip-time (``rg_breakdown``, paper Fig. 10);
  * per-(segment key, segment value) sub-ledgers with their own
    denominators (``segment_report``, paper §5's Simpson's-paradox guard);
  * a windowed MPG time series (hourly/daily SG/RG/PG, the Fig. 5/11
    timeline shapes) — intervals crossing a window boundary are split
    proportionally;
  * subscriber hooks, so exporters/monitors observe the event stream
    without a second ledger.

Memory is O(#jobs + #segments + #windows), never O(#events), unless
``retain_intervals=True`` is requested for debugging/back-compat (the
legacy ``sim.intervals`` attribute).  ``repro.core.goodput``'s
``compute_goodput`` / ``segment_goodput`` / ``rg_breakdown`` are thin
wrappers over a throwaway ledger, so the two paths cannot drift.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.core.goodput import (ALLOCATED_PHASES, PRODUCTIVE_PHASES,
                                GoodputReport, Interval, Phase)


@dataclasses.dataclass
class _Acc:
    """Incremental MPG accumulator: the three chip-time sums plus the
    per-phase split (QUEUED/PARTIAL included — per-segment SG numerators,
    Fig. 16, need the waiting phases too)."""
    allocated: float = 0.0
    productive: float = 0.0
    ideal: float = 0.0
    phase: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, phase: Phase, chip_time: float, pg: float):
        self.phase[phase.value] = self.phase.get(phase.value, 0.0) + chip_time
        if phase in ALLOCATED_PHASES:
            self.allocated += chip_time
        if phase in PRODUCTIVE_PHASES:
            self.productive += chip_time
            self.ideal += chip_time * pg

    def report(self, capacity_chip_time: float) -> GoodputReport:
        sg = self.allocated / capacity_chip_time if capacity_chip_time else 0.0
        rg = self.productive / self.allocated if self.allocated else 0.0
        pg = self.ideal / self.productive if self.productive else 0.0
        return GoodputReport(sg=sg, rg=rg, pg=pg,
                             capacity_chip_time=capacity_chip_time,
                             allocated_chip_time=self.allocated,
                             productive_chip_time=self.productive,
                             ideal_chip_time=self.ideal)


class GoodputLedger:
    """Append-only goodput event sink with streaming accumulators.

    Parameters
    ----------
    capacity_chip_time:
        Fleet capacity denominator for SG.  Emitting layers call
        :meth:`add_capacity` instead when several clusters share one
        ledger; :meth:`report` also accepts an explicit override.
    window:
        Width (seconds) of the MPG time-series buckets (default: hourly).
    retain_intervals:
        Keep the raw ``Interval`` list (O(#events) memory).  Default on
        for interactive/simulator use where tests inspect the stream;
        turn off for fleet-scale runs (see ``benchmarks/ledger_scale.py``).
    """

    def __init__(self, capacity_chip_time: float = 0.0,
                 window: float = 3600.0,
                 retain_intervals: bool = True):
        self.capacity_chip_time = capacity_chip_time
        self.window = window
        self.retain_intervals = retain_intervals
        self.intervals: Optional[List[Interval]] = \
            [] if retain_intervals else None
        self.n_events = 0
        self._totals = _Acc()
        # segment key -> segment value -> accumulator
        self._segments: Dict[str, Dict[str, _Acc]] = \
            defaultdict(lambda: defaultdict(_Acc))
        # window index -> accumulator (for the SG/RG/PG time series)
        self._windows: Dict[int, _Acc] = defaultdict(_Acc)
        # job -> productive chip-time: lets report() re-weight PG with a
        # pg_by_job table supplied *after* the stream (legacy API shape)
        self._job_productive: Dict[str, float] = defaultdict(float)
        self._subscribers: List[Callable[[Interval], None]] = []
        self._event_subscribers: List[Callable[[Interval, float], None]] = []

    # ---- event ingestion --------------------------------------------------
    def subscribe(self, fn: Callable[[Interval], None]) -> None:
        """Call ``fn(interval)`` on every recorded event."""
        self._subscribers.append(fn)

    def subscribe_events(self, fn: Callable[[Interval, float], None]) -> None:
        """Call ``fn(interval, pg)`` on every recorded event — the pg-aware
        hook trace recorders need (``repro.fleet.trace``): replaying the
        observed ``(interval, pg)`` stream reproduces this ledger's totals
        bit-for-bit."""
        self._event_subscribers.append(fn)

    def add_capacity(self, chip_time: float) -> None:
        """Add an emitter's capacity to the SG denominator (multi-cluster)."""
        self.capacity_chip_time += chip_time

    def record(self, iv: Interval, pg: float = 1.0) -> None:
        """Ingest one interval; ``pg`` weights its STEP time into ideal
        chip-time (the Program Goodput of the job's compiled program)."""
        ct = iv.chip_time
        if ct <= 0.0:
            return
        self.n_events += 1
        self._totals.add(iv.phase, ct, pg)
        if iv.phase in PRODUCTIVE_PHASES:
            self._job_productive[iv.job_id] += ct
        for key, val in iv.segment.items():
            self._segments[key][val].add(iv.phase, ct, pg)
        self._add_windowed(iv, pg)
        if self.retain_intervals:
            self.intervals.append(iv)
        for fn in self._subscribers:
            fn(iv)
        for fn in self._event_subscribers:
            fn(iv, pg)

    def emit(self, job_id: str, phase: Phase, t0: float, t1: float,
             chips: int, segment: Optional[Dict[str, str]] = None,
             pg: float = 1.0) -> None:
        """Convenience constructor-and-record for emitting layers."""
        if t1 <= t0:
            return
        self.record(Interval(job_id=job_id, phase=phase, t0=t0, t1=t1,
                             chips=chips, segment=segment or {}), pg=pg)

    def extend(self, intervals: Iterable[Interval],
               pg_by_job: Optional[Dict[str, float]] = None) -> None:
        """Batch-ingest an interval stream (legacy-list compatibility)."""
        table = pg_by_job or {}
        for iv in intervals:
            self.record(iv, pg=table.get(iv.job_id, 1.0))

    def _add_windowed(self, iv: Interval, pg: float) -> None:
        w = self.window
        if w <= 0 or not math.isfinite(iv.t0) or not math.isfinite(iv.t1):
            return
        i0 = int(iv.t0 // w)
        i1 = int(iv.t1 // w) if iv.t1 % w else int(iv.t1 // w) - 1
        if i1 < i0:
            i1 = i0
        for widx in range(i0, i1 + 1):
            lo = max(iv.t0, widx * w)
            hi = min(iv.t1, (widx + 1) * w)
            if hi > lo:
                self._windows[widx].add(iv.phase, (hi - lo) * iv.chips, pg)

    # ---- reporting --------------------------------------------------------
    def report(self, capacity_chip_time: Optional[float] = None,
               pg_by_job: Optional[Dict[str, float]] = None) -> GoodputReport:
        """Aggregate MPG report.  With ``pg_by_job``, PG is recomputed from
        the per-job productive sums (exactly the legacy ``compute_goodput``
        semantics); otherwise the streamed per-event ``pg`` weights apply."""
        cap = (self.capacity_chip_time if capacity_chip_time is None
               else capacity_chip_time)
        acc = self._totals
        if pg_by_job is not None:
            acc = _Acc(allocated=self._totals.allocated,
                       productive=self._totals.productive,
                       ideal=sum(ct * pg_by_job.get(j, 1.0)
                                 for j, ct in
                                 sorted(self._job_productive.items())))
        return acc.report(cap)

    def segment_report(self, key: str,
                       capacity_by_segment: Optional[Dict[str, float]] = None
                       ) -> Dict[str, GoodputReport]:
        """Per-segment MPG with per-segment denominators (paper §5)."""
        caps = capacity_by_segment or {}
        return {seg: acc.report(caps.get(seg, 0.0))
                for seg, acc in sorted(self._segments.get(key, {}).items())}

    def rg_breakdown(self) -> Dict[str, float]:
        """Allocated chip-time shares by phase (paper Fig. 10)."""
        out = {p.value: self._totals.phase[p.value]
               for p in Phase
               if p in ALLOCATED_PHASES and
               self._totals.phase.get(p.value, 0.0) > 0}
        total = sum(out.values()) or 1.0
        return {k: v / total for k, v in sorted(out.items())}

    def phase_chip_time(self, phase: Phase) -> float:
        """Raw chip-time sum for one phase (incl. QUEUED/PARTIAL)."""
        return self._totals.phase.get(phase.value, 0.0)

    def segment_phase_chip_time(self, key: str) -> Dict[str, Dict[str, float]]:
        """Per-segment per-phase chip-time sums — the building blocks for
        per-class SG numerators (Fig. 16: PARTIAL vs allocated by class)."""
        return {seg: dict(acc.phase)
                for seg, acc in sorted(self._segments.get(key, {}).items())}

    def series(self, capacity_chips: Optional[float] = None
               ) -> List[Dict[str, float]]:
        """Windowed SG/RG/PG/MPG time series (Fig. 5/11 timelines).

        ``capacity_chips`` sets each window's SG denominator to
        ``capacity_chips * window``; defaults to spreading the ledger's
        total capacity uniformly over the observed window span.
        """
        if not self._windows:
            return []
        idxs = sorted(self._windows)
        if capacity_chips is not None:
            win_cap = capacity_chips * self.window
        else:
            span = (idxs[-1] - idxs[0] + 1) * self.window
            win_cap = (self.capacity_chip_time * self.window / span
                       if span else 0.0)
        out = []
        for widx in idxs:
            rep = self._windows[widx].report(win_cap)
            out.append({"t0": widx * self.window,
                        "t1": (widx + 1) * self.window,
                        "sg": rep.sg, "rg": rep.rg, "pg": rep.pg,
                        "mpg": rep.mpg,
                        "allocated_chip_time": rep.allocated_chip_time,
                        "productive_chip_time": rep.productive_chip_time,
                        "ideal_chip_time": rep.ideal_chip_time})
        return out

    def totals(self) -> Dict[str, object]:
        """The exact accumulator state a trace replay must reproduce
        bit-for-bit: event count, capacity, the three MPG chip-time sums,
        and the per-phase split.  Floats are returned unrounded (and
        serialize exactly through JSON's shortest-roundtrip repr), so
        golden-trace tests can assert ``replayed.totals() == trace.totals``
        with plain equality."""
        return {
            "n_events": self.n_events,
            "capacity_chip_time": self.capacity_chip_time,
            "allocated_chip_time": self._totals.allocated,
            "productive_chip_time": self._totals.productive,
            "ideal_chip_time": self._totals.ideal,
            "by_phase": dict(self._totals.phase),
        }

    # ---- introspection ----------------------------------------------------
    def state_size(self) -> Dict[str, int]:
        """Number of tracked accumulator entries — the memory story told by
        ``benchmarks/ledger_scale.py`` (O(state) vs O(events))."""
        return {
            "phases": len(self._totals.phase),
            "segment_keys": len(self._segments),
            "segment_cells": sum(len(v) for v in self._segments.values()),
            "windows": len(self._windows),
            "jobs": len(self._job_productive),
            "retained_intervals": len(self.intervals or ()),
        }
