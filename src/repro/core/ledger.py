"""Streaming GoodputLedger: one fleet-wide accounting sink (paper §4-§5).

The paper's central move is a *single* MPG = SG x RG x PG accounting that
spans the whole stack — scheduler, runtime, and program layers.  Before
this module each layer kept its own ``List[Interval]`` and every report
re-walked the full list; a month of fleet time at production job counts
materializes millions of intervals just to produce four numbers.

``GoodputLedger`` is an append-only event sink with O(1)-per-event
incremental accumulators:

  * aggregate allocated / productive / ideal chip-time (the MPG inputs);
  * per-phase chip-time (``rg_breakdown``, paper Fig. 10);
  * per-(segment key, segment value) sub-ledgers with their own
    denominators (``segment_report``, paper §5's Simpson's-paradox guard);
  * a windowed MPG time series (hourly/daily SG/RG/PG, the Fig. 5/11
    timeline shapes) — intervals crossing a window boundary are split
    proportionally;
  * subscriber hooks, so exporters/monitors observe the event stream
    without a second ledger.

Memory is O(#jobs + #segments + #windows), never O(#events), unless
``retain_intervals=True`` is requested for debugging/back-compat (the
legacy ``sim.intervals`` attribute).  ``repro.core.goodput``'s
``compute_goodput`` / ``segment_goodput`` / ``rg_breakdown`` are thin
wrappers over a throwaway ledger, so the two paths cannot drift.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.goodput import (ALLOCATED_PHASES, PRODUCTIVE_PHASES,
                                GoodputReport, Interval, Phase)

try:                               # numpy vectorizes the per-event derived
    import numpy as _np            # quantities in add_intervals; the pure-
except ModuleNotFoundError:        # python fallback is value-identical
    _np = None

# resolved segment-accumulator lists are cached per interned segment-dict
# identity; past this many distinct dicts the caller is clearly not
# interning and caching would grow per event, so we stop inserting
_SEG_CACHE_CAP = 4096

# hot-loop classification pinned onto the Phase members themselves: the
# batched ingest path reads plain attributes instead of paying an
# enum-hash set lookup per accumulator per event
for _p in Phase:
    _p._x_alloc = _p in ALLOCATED_PHASES
    _p._x_prod = _p in PRODUCTIVE_PHASES
del _p


class IntervalBatch:
    """A columnar slice of the event stream: parallel sequences, one row
    per recorded event (zero-chip-time rows are filtered out before batch
    subscribers see them, exactly like :meth:`GoodputLedger.record`).

    ``chip_times[i]`` is precomputed ``(t1[i] - t0[i]) * chips[i]`` — the
    same IEEE operations :attr:`Interval.chip_time` performs, so consumers
    mirroring the ledger stay bit-for-bit."""

    __slots__ = ("job_ids", "phases", "t0", "t1", "chips", "pgs",
                 "segments", "chip_times")

    def __init__(self, job_ids, phases, t0, t1, chips, pgs, segments,
                 chip_times):
        self.job_ids = job_ids
        self.phases = phases
        self.t0 = t0
        self.t1 = t1
        self.chips = chips
        self.pgs = pgs
        self.segments = segments
        self.chip_times = chip_times

    def __len__(self) -> int:
        return len(self.t0)

    def intervals(self) -> List[Interval]:
        """Materialize Interval objects (for per-event consumers)."""
        return [Interval(job_id=j, phase=p, t0=a, t1=b, chips=c, segment=s)
                for j, p, a, b, c, s in zip(self.job_ids, self.phases,
                                            self.t0, self.t1, self.chips,
                                            self.segments)]


@dataclasses.dataclass
class _Acc:
    """Incremental MPG accumulator: the three chip-time sums plus the
    per-phase split (QUEUED/PARTIAL included — per-segment SG numerators,
    Fig. 16, need the waiting phases too)."""
    allocated: float = 0.0
    productive: float = 0.0
    ideal: float = 0.0
    phase: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, phase: Phase, chip_time: float, pg: float):
        self.phase[phase.value] = self.phase.get(phase.value, 0.0) + chip_time
        if phase in ALLOCATED_PHASES:
            self.allocated += chip_time
        if phase in PRODUCTIVE_PHASES:
            self.productive += chip_time
            self.ideal += chip_time * pg

    def report(self, capacity_chip_time: float) -> GoodputReport:
        sg = self.allocated / capacity_chip_time if capacity_chip_time else 0.0
        rg = self.productive / self.allocated if self.allocated else 0.0
        pg = self.ideal / self.productive if self.productive else 0.0
        return GoodputReport(sg=sg, rg=rg, pg=pg,
                             capacity_chip_time=capacity_chip_time,
                             allocated_chip_time=self.allocated,
                             productive_chip_time=self.productive,
                             ideal_chip_time=self.ideal)


class GoodputLedger:
    """Append-only goodput event sink with streaming accumulators.

    Parameters
    ----------
    capacity_chip_time:
        Fleet capacity denominator for SG.  Emitting layers call
        :meth:`add_capacity` instead when several clusters share one
        ledger; :meth:`report` also accepts an explicit override.
    window:
        Width (seconds) of the MPG time-series buckets (default: hourly).
    retain_intervals:
        Keep the raw ``Interval`` list (O(#events) memory).  Default on
        for interactive/simulator use where tests inspect the stream;
        turn off for fleet-scale runs (see ``benchmarks/ledger_scale.py``).
    """

    def __init__(self, capacity_chip_time: float = 0.0,
                 window: float = 3600.0,
                 retain_intervals: bool = True):
        self.capacity_chip_time = capacity_chip_time
        self.window = window
        self.retain_intervals = retain_intervals
        self.intervals: Optional[List[Interval]] = \
            [] if retain_intervals else None
        self.n_events = 0
        self._totals = _Acc()
        # segment key -> segment value -> accumulator
        self._segments: Dict[str, Dict[str, _Acc]] = \
            defaultdict(lambda: defaultdict(_Acc))
        # window index -> accumulator (for the SG/RG/PG time series)
        self._windows: Dict[int, _Acc] = defaultdict(_Acc)
        # job -> productive chip-time: lets report() re-weight PG with a
        # pg_by_job table supplied *after* the stream (legacy API shape)
        self._job_productive: Dict[str, float] = defaultdict(float)
        self._subscribers: List[Callable[[Interval], None]] = []
        # (per-event fn, optional batch fn) pairs — see subscribe_events
        self._event_subscribers: List[Tuple[Callable[[Interval, float], None],
                                            Optional[Callable]]] = []
        # id(segment dict) -> (dict, resolved accumulator list); the
        # batched ingest path resolves each *interned* segment dict's
        # (key, value) accumulators once instead of per event
        self._seg_acc_cache: Dict[int, Tuple[Dict[str, str], List[_Acc]]] = {}

    # ---- event ingestion --------------------------------------------------
    def subscribe(self, fn: Callable[[Interval], None]) -> None:
        """Call ``fn(interval)`` on every recorded event."""
        self._subscribers.append(fn)

    def subscribe_events(self, fn: Callable[[Interval, float], None],
                         batch_fn: Optional[Callable[["IntervalBatch"],
                                                     None]] = None) -> None:
        """Call ``fn(interval, pg)`` on every recorded event — the pg-aware
        hook trace recorders need (``repro.fleet.trace``): replaying the
        observed ``(interval, pg)`` stream reproduces this ledger's totals
        bit-for-bit.

        ``batch_fn``, when given, makes the subscriber *batch-aware*: the
        columnar ingest path (:meth:`add_intervals`) delivers one
        :class:`IntervalBatch` per flush instead of a per-event callback —
        same events, same order, no per-interval Python dispatch.  A
        subscriber without ``batch_fn`` still sees every event (the batch
        path materializes Interval objects for it)."""
        self._event_subscribers.append((fn, batch_fn))

    def add_capacity(self, chip_time: float) -> None:
        """Add an emitter's capacity to the SG denominator (multi-cluster)."""
        self.capacity_chip_time += chip_time

    def record(self, iv: Interval, pg: float = 1.0) -> None:
        """Ingest one interval; ``pg`` weights its STEP time into ideal
        chip-time (the Program Goodput of the job's compiled program)."""
        ct = iv.chip_time
        if ct <= 0.0:
            return
        self.n_events += 1
        self._totals.add(iv.phase, ct, pg)
        if iv.phase in PRODUCTIVE_PHASES:
            self._job_productive[iv.job_id] += ct
        for key, val in iv.segment.items():
            self._segments[key][val].add(iv.phase, ct, pg)
        self._add_windowed(iv.phase, iv.t0, iv.t1, iv.chips, pg)
        if self.retain_intervals:
            self.intervals.append(iv)
        for fn in self._subscribers:
            fn(iv)
        for fn, _ in self._event_subscribers:
            fn(iv, pg)

    def emit(self, job_id: str, phase: Phase, t0: float, t1: float,
             chips: int, segment: Optional[Dict[str, str]] = None,
             pg: float = 1.0) -> None:
        """Convenience constructor-and-record for emitting layers."""
        if t1 <= t0:
            return
        self.record(Interval(job_id=job_id, phase=phase, t0=t0, t1=t1,
                             chips=chips, segment=segment or {}), pg=pg)

    def extend(self, intervals: Iterable[Interval],
               pg_by_job: Optional[Dict[str, float]] = None) -> None:
        """Batch-ingest an interval stream (legacy-list compatibility)."""
        table = pg_by_job or {}
        for iv in intervals:
            self.record(iv, pg=table.get(iv.job_id, 1.0))

    def _add_windowed(self, phase: Phase, t0: float, t1: float, chips: int,
                      pg: float) -> None:
        w = self.window
        if w <= 0 or not math.isfinite(t0) or not math.isfinite(t1):
            return
        i0 = int(t0 // w)
        i1 = int(t1 // w) if t1 % w else int(t1 // w) - 1
        if i1 < i0:
            i1 = i0
        for widx in range(i0, i1 + 1):
            lo = max(t0, widx * w)
            hi = min(t1, (widx + 1) * w)
            if hi > lo:
                self._windows[widx].add(phase, (hi - lo) * chips, pg)

    def add_intervals(self, job_ids: Sequence[str], phases: Sequence[Phase],
                      t0: Sequence[float], t1: Sequence[float],
                      chips: Sequence[int], pgs: Sequence[float],
                      segments: Sequence[Dict[str, str]]) -> int:
        """Columnar batch ingest: one call for many events.

        Semantically identical to calling :meth:`record` once per row in
        order — the accumulators receive the *same addends in the same
        order*, so ``totals()`` after a batched stream is bit-for-bit
        equal to the per-event stream.  The speed comes from what batching
        makes possible without touching that order:

          * derived chip-times are computed elementwise over the whole
            batch (numpy when available; IEEE ops are identical per
            element either way);
          * (key, value) sub-ledger accumulators are resolved once per
            *interned* segment dict instead of per event;
          * batch-aware subscribers (``subscribe_events(fn, batch_fn)``)
            get one :class:`IntervalBatch` per flush; ``Interval`` objects
            are only materialized when a legacy per-event consumer (or
            ``retain_intervals``) needs them.

        Returns the number of events actually recorded (zero-chip-time
        rows are skipped, exactly like ``record``)."""
        n = len(t0)
        if n == 0:
            return 0
        if _np is not None and n >= 16:
            cts = ((_np.asarray(t1, dtype=_np.float64)
                    - _np.asarray(t0, dtype=_np.float64))
                   * _np.asarray(chips, dtype=_np.float64)).tolist()
        else:
            cts = [(b - a) * c for a, b, c in zip(t0, t1, chips)]

        totals = self._totals
        tphase = totals.phase
        segs_root = self._segments
        seg_cache = self._seg_acc_cache
        jobprod = self._job_productive
        retained = self.intervals
        per_event = (bool(self._subscribers)
                     or any(bfn is None for _, bfn in self._event_subscribers))
        need_ivs = retained is not None or per_event

        windows = self._windows
        w = self.window
        w_ok = w > 0
        isfinite = math.isfinite
        made: List[Optional[Interval]] = [] if need_ivs else None
        kept = 0
        skipped = False
        for i in range(n):
            ct = cts[i]
            if ct <= 0.0:
                skipped = True
                if need_ivs:
                    made.append(None)
                continue
            kept += 1
            ph = phases[i]
            pg = pgs[i]
            seg = segments[i]
            # ph._value_ / ph._x_alloc / ph._x_prod are plain attribute
            # reads standing in for ph.value (a DynamicClassAttribute
            # descriptor) and the ALLOCATED/PRODUCTIVE set lookups; the
            # inlined _Acc.add bodies below perform the identical float
            # operations in the identical order as acc.add(ph, ct, pg)
            pv = ph._value_
            is_alloc = ph._x_alloc
            is_prod = ph._x_prod
            tphase[pv] = tphase.get(pv, 0.0) + ct
            if is_alloc:
                totals.allocated += ct
            if is_prod:
                totals.productive += ct
                totals.ideal += ct * pg
                jobprod[job_ids[i]] += ct
            entry = seg_cache.get(id(seg))
            if entry is not None and entry[0] is seg:
                accs = entry[1]
            else:
                accs = [segs_root[k][v] for k, v in seg.items()]
                if len(seg_cache) < _SEG_CACHE_CAP:
                    seg_cache[id(seg)] = (seg, accs)
            for acc in accs:
                aph = acc.phase
                aph[pv] = aph.get(pv, 0.0) + ct
                if is_alloc:
                    acc.allocated += ct
                if is_prod:
                    acc.productive += ct
                    acc.ideal += ct * pg
            a = t0[i]
            b = t1[i]
            if w_ok and isfinite(a) and isfinite(b):
                i0 = int(a // w)
                i1 = int(b // w) if b % w else int(b // w) - 1
                if i1 <= i0:
                    # single-window fast path: same max/min clamps as
                    # _add_windowed's loop body for widx == i0
                    lo = max(a, i0 * w)
                    hi = min(b, (i0 + 1) * w)
                    if hi > lo:
                        wct = (hi - lo) * chips[i]
                        wacc = windows[i0]
                        wph = wacc.phase
                        wph[pv] = wph.get(pv, 0.0) + wct
                        if is_alloc:
                            wacc.allocated += wct
                        if is_prod:
                            wacc.productive += wct
                            wacc.ideal += wct * pg
                else:
                    self._add_windowed(ph, a, b, chips[i], pg)
            if need_ivs:
                made.append(Interval(job_id=job_ids[i], phase=ph, t0=t0[i],
                                     t1=t1[i], chips=chips[i], segment=seg))
        self.n_events += kept
        if kept == 0:
            return 0

        if need_ivs:
            kept_rows = [(iv, pgs[i]) for i, iv in enumerate(made)
                         if iv is not None]
            if retained is not None:
                retained.extend(iv for iv, _ in kept_rows)
            for fn in self._subscribers:
                for iv, _ in kept_rows:
                    fn(iv)
        batch = None
        for fn, bfn in self._event_subscribers:
            if bfn is not None:
                if batch is None:
                    batch = self._make_batch(job_ids, phases, t0, t1, chips,
                                             pgs, segments, cts, skipped)
                bfn(batch)
            else:
                for iv, pg in kept_rows:
                    fn(iv, pg)
        return kept

    def _make_batch(self, job_ids, phases, t0, t1, chips, pgs, segments,
                    cts, skipped) -> "IntervalBatch":
        if not skipped:
            return IntervalBatch(list(job_ids), list(phases), list(t0),
                                 list(t1), list(chips), list(pgs),
                                 list(segments), cts)
        keep = [i for i, ct in enumerate(cts) if ct > 0.0]
        pick = lambda seq: [seq[i] for i in keep]      # noqa: E731
        return IntervalBatch(pick(job_ids), pick(phases), pick(t0), pick(t1),
                             pick(chips), pick(pgs), pick(segments),
                             pick(cts))

    # ---- reporting --------------------------------------------------------
    def report(self, capacity_chip_time: Optional[float] = None,
               pg_by_job: Optional[Dict[str, float]] = None) -> GoodputReport:
        """Aggregate MPG report.  With ``pg_by_job``, PG is recomputed from
        the per-job productive sums (exactly the legacy ``compute_goodput``
        semantics); otherwise the streamed per-event ``pg`` weights apply."""
        cap = (self.capacity_chip_time if capacity_chip_time is None
               else capacity_chip_time)
        acc = self._totals
        if pg_by_job is not None:
            acc = _Acc(allocated=self._totals.allocated,
                       productive=self._totals.productive,
                       ideal=sum(ct * pg_by_job.get(j, 1.0)
                                 for j, ct in
                                 sorted(self._job_productive.items())))
        return acc.report(cap)

    def segment_report(self, key: str,
                       capacity_by_segment: Optional[Dict[str, float]] = None
                       ) -> Dict[str, GoodputReport]:
        """Per-segment MPG with per-segment denominators (paper §5)."""
        caps = capacity_by_segment or {}
        return {seg: acc.report(caps.get(seg, 0.0))
                for seg, acc in sorted(self._segments.get(key, {}).items())}

    def rg_breakdown(self) -> Dict[str, float]:
        """Allocated chip-time shares by phase (paper Fig. 10)."""
        out = {p.value: self._totals.phase[p.value]
               for p in Phase
               if p in ALLOCATED_PHASES and
               self._totals.phase.get(p.value, 0.0) > 0}
        total = sum(out.values()) or 1.0
        return {k: v / total for k, v in sorted(out.items())}

    def phase_chip_time(self, phase: Phase) -> float:
        """Raw chip-time sum for one phase (incl. QUEUED/PARTIAL)."""
        return self._totals.phase.get(phase.value, 0.0)

    def segment_phase_chip_time(self, key: str) -> Dict[str, Dict[str, float]]:
        """Per-segment per-phase chip-time sums — the building blocks for
        per-class SG numerators (Fig. 16: PARTIAL vs allocated by class)."""
        return {seg: dict(acc.phase)
                for seg, acc in sorted(self._segments.get(key, {}).items())}

    def series(self, capacity_chips: Optional[float] = None
               ) -> List[Dict[str, float]]:
        """Windowed SG/RG/PG/MPG time series (Fig. 5/11 timelines).

        ``capacity_chips`` sets each window's SG denominator to
        ``capacity_chips * window``; defaults to spreading the ledger's
        total capacity uniformly over the observed window span.
        """
        if not self._windows:
            return []
        idxs = sorted(self._windows)
        if capacity_chips is not None:
            win_cap = capacity_chips * self.window
        else:
            span = (idxs[-1] - idxs[0] + 1) * self.window
            win_cap = (self.capacity_chip_time * self.window / span
                       if span else 0.0)
        out = []
        for widx in idxs:
            rep = self._windows[widx].report(win_cap)
            out.append({"t0": widx * self.window,
                        "t1": (widx + 1) * self.window,
                        "sg": rep.sg, "rg": rep.rg, "pg": rep.pg,
                        "mpg": rep.mpg,
                        "allocated_chip_time": rep.allocated_chip_time,
                        "productive_chip_time": rep.productive_chip_time,
                        "ideal_chip_time": rep.ideal_chip_time})
        return out

    def tail_series(self, n_windows: int,
                    capacity_chips: float) -> List[Dict[str, float]]:
        """The most recent ``n_windows`` rows of the windowed SG/RG/PG
        series — the online controller's observation stream.  Same row
        shape as :meth:`series`, but O(n_windows) instead of walking every
        window, so a per-boundary observer stays cheap on long horizons."""
        if not self._windows or n_windows <= 0:
            return []
        idxs = sorted(self._windows)[-n_windows:]
        win_cap = capacity_chips * self.window
        out = []
        for widx in idxs:
            rep = self._windows[widx].report(win_cap)
            out.append({"t0": widx * self.window,
                        "t1": (widx + 1) * self.window,
                        "sg": rep.sg, "rg": rep.rg, "pg": rep.pg,
                        "mpg": rep.mpg,
                        "allocated_chip_time": rep.allocated_chip_time,
                        "productive_chip_time": rep.productive_chip_time,
                        "ideal_chip_time": rep.ideal_chip_time})
        return out

    def totals(self) -> Dict[str, object]:
        """The exact accumulator state a trace replay must reproduce
        bit-for-bit: event count, capacity, the three MPG chip-time sums,
        and the per-phase split.  Floats are returned unrounded (and
        serialize exactly through JSON's shortest-roundtrip repr), so
        golden-trace tests can assert ``replayed.totals() == trace.totals``
        with plain equality."""
        return {
            "n_events": self.n_events,
            "capacity_chip_time": self.capacity_chip_time,
            "allocated_chip_time": self._totals.allocated,
            "productive_chip_time": self._totals.productive,
            "ideal_chip_time": self._totals.ideal,
            "by_phase": dict(self._totals.phase),
        }

    # ---- introspection ----------------------------------------------------
    def state_size(self) -> Dict[str, int]:
        """Number of tracked accumulator entries — the memory story told by
        ``benchmarks/ledger_scale.py`` (O(state) vs O(events))."""
        return {
            "phases": len(self._totals.phase),
            "segment_keys": len(self._segments),
            "segment_cells": sum(len(v) for v in self._segments.values()),
            "windows": len(self._windows),
            "jobs": len(self._job_productive),
            "retained_intervals": len(self.intervals or ()),
        }
