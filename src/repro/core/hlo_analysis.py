"""Post-compile HLO analysis: collective-traffic accounting with while-loop
trip-count multiplication.

``compiled.cost_analysis()`` counts while bodies ONCE (verified empirically
— see EXPERIMENTS.md §Dry-run notes), so collective bytes inside a
``lax.scan`` over layers would be undercounted by ~L.  This parser walks the
optimized HLO module text, finds every collective op, and multiplies by the
product of enclosing while trip counts (recovered from the loop condition's
comparison constant — exact for scan-lowered loops).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:to_apply|called_computations=\{)=?%?([\w\.\-]+)")


def shape_bytes(shape_str: str) -> int:
    """Bytes of one 'f32[128,256]' (or tuple '(f32[..], bf16[..])') string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip().startswith("}"):
            cur = None
            continue
        comps[cur].append(line)
    return comps


def _entry_name(hlo: str, comps) -> str:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: computation named main.*
    for name in comps:
        if name.startswith("main"):
            return name
    return next(iter(comps))


def _trip_count(cond_lines: List[str]) -> int:
    """Largest integer constant in the loop condition (exact for scan)."""
    best = 1
    for ln in cond_lines:
        for c in _CONST_RE.findall(ln):
            best = max(best, int(c))
    return best


def _op_kind(line: str):
    for kind in COLLECTIVE_KINDS:
        token = f" {kind}("
        start_token = f" {kind}-start("
        if token in line:
            return kind
        if start_token in line:
            return kind
    return None


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)           # iota form: [n_groups, group_size]
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_BRACE_RE.search(line)     # explicit form: {{0,1},{2,3},...}
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def _operand_bytes(line: str, kind: str) -> float:
    """Operand bytes of a collective op, recovered from its RESULT shape.

    Scheduled HLO prints operands without types, so we use the result shape:
      all-reduce / all-to-all / collective-permute: operand == result;
      all-gather: operand = result / group_size;
      reduce-scatter: operand (full input) = result * group_size.
    """
    m = re.search(rf"=\s*(.*?)\s{re.escape(kind)}(?:-start)?\(", line)
    if not m:
        return 0.0
    result = shape_bytes(m.group(1))
    g = _group_size(line)
    if kind == "all-gather":
        return result / g
    if kind == "reduce-scatter":
        return result * g
    return float(result)


def collective_stats(hlo_text: str) -> CollectiveStats:
    comps = _split_computations(hlo_text)
    entry = _entry_name(hlo_text, comps)
    bytes_by_kind: Dict[str, float] = defaultdict(float)
    count_by_kind: Dict[str, int] = defaultdict(int)
    visiting = set()

    def walk(name: str, mult: float):
        if name not in comps or name in visiting:
            return
        visiting.add(name)
        for line in comps[name]:
            kind = _op_kind(line)
            if kind and "-done(" not in line:
                bytes_by_kind[kind] += mult * _operand_bytes(line, kind)
                count_by_kind[kind] += 1
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                trips = _trip_count(comps.get(cond, []))
                walk(body, mult * trips)
                continue
            # conditionals / calls (not collectives' to_apply reducers)
            if " call(" in line or "conditional(" in line:
                for callee in re.findall(r"(?:to_apply|branch_computations=\{[^}]*)=?%?([\w\.\-]+)", line):
                    walk(callee, mult)
        visiting.discard(name)

    walk(entry, 1.0)
    return CollectiveStats(dict(bytes_by_kind), dict(count_by_kind))


def top_collectives(hlo_text: str, n: int = 10) -> List[dict]:
    """The n largest collectives by bytes x enclosing-loop trips — the
    hillclimb targeting tool (what should I shrink first?)."""
    comps = _split_computations(hlo_text)
    entry = _entry_name(hlo_text, comps)
    out: List[dict] = []
    visiting = set()

    def walk(name: str, mult: float):
        if name not in comps or name in visiting:
            return
        visiting.add(name)
        for line in comps[name]:
            kind = _op_kind(line)
            if kind and "-done(" not in line:
                b = _operand_bytes(line, kind)
                meta = re.search(r'op_name="([^"]+)"', line)
                out.append({
                    "kind": kind,
                    "bytes_once": b,
                    "trips": mult,
                    "bytes_total": b * mult,
                    "op_name": meta.group(1)[-120:] if meta else "?",
                })
            m = _WHILE_RE.search(line)
            if m:
                walk(m.group(2), mult * _trip_count(comps.get(m.group(1), [])))
        visiting.discard(name)

    walk(entry, 1.0)
    out.sort(key=lambda r: -r["bytes_total"])
    return out[:n]


def while_trip_counts(hlo_text: str) -> List[Tuple[str, int]]:
    comps = _split_computations(hlo_text)
    out = []
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                out.append((m.group(2), _trip_count(comps.get(m.group(1), []))))
    return out
