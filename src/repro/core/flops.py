"""Analytic MODEL_FLOPS — the paper's Program-Goodput numerator.

Per assignment spec: MODEL_FLOPS = 6*N*D for training (fwd+bwd) and 2*N*D
for inference, with N = active parameters (MoE activates top-k only) and
D = tokens processed.  Attention score FLOPs are intentionally excluded —
the HLO_FLOPs / MODEL_FLOPS ratio then surfaces attention cost, remat
recompute, and masking waste as "non-useful" compute.
"""
from __future__ import annotations

from repro.models.config import ModelConfig, ShapeConfig


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n = cfg.num_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch


def model_bytes_min(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Lower-bound HBM traffic: every active parameter read once (bf16).

    For decode this is the classic weights-bound roofline; for train it
    undercounts activations deliberately (it is a floor, not an estimate).
    """
    n = cfg.num_active_params()
    return 2.0 * n
