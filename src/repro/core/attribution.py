"""Cross-layer MPG attribution waterfall (paper §6, Figs 14–15).

"Where did the goodput go?" — the paper answers by decomposing fleet
capacity chip-time into productive time plus named losses, each charged
to the stack layer responsible (model, data, framework, compiler,
scheduling, hardware).  :class:`AttributionWaterfall` is a streaming
subscriber on a :class:`~repro.core.ledger.GoodputLedger`: it keeps
O(#layers x #phases) accumulator state — never an interval list — and
maintains an *exact* partition of capacity chip-time:

    capacity = ideal + program_gap + Σ layer losses + unallocated

where ``program_gap = productive - ideal`` (the Program-Goodput gap,
charged to the model layer) and ``unallocated = capacity - allocated``
(capacity no job held, charged to the scheduling layer).  QUEUED/PARTIAL
waiting time is *demand-side* (a job waiting does not consume capacity),
so it is reported separately (``waits``) and excluded from the capacity
partition — double-counting it against capacity is the classic
conservation bug the exactness contract exists to catch.

Two levels of exactness:

  * the waterfall mirrors the ledger's float accumulators operation-for-
    operation (same event stream, same order), so
    ``assert_conserves(ledger)`` compares its totals against
    ``ledger.totals()`` with plain ``==`` — bit-for-bit;
  * every event's chip-time is *also* accumulated per (layer, phase)
    cell in exact arithmetic (integers scaled by the subnormal quantum
    ``2**-1074``, to which every finite float converts losslessly — same
    exactness as ``fractions.Fraction``, at integer-addition cost), so
    "Σ buckets == allocated" is checked with no rounding at all — a
    misrouted event cannot hide in float slack.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from fractions import Fraction
from typing import Dict, List, Optional, Tuple

from repro.core.goodput import (ALLOCATED_PHASES, PRODUCTIVE_PHASES,
                                Interval, Layer, Phase, layer_of,
                                loss_bucket)
from repro.core.ledger import GoodputLedger, _Acc

# Exact accumulation representation: every finite float is an integer
# multiple of 2**-1074 (the subnormal quantum), so chip-times are stored
# as plain ints scaled by 2**_SHIFT — integer addition is exact and an
# order of magnitude cheaper than Fraction arithmetic, and converts
# losslessly to Fraction(x, 1 << _SHIFT) at the read sites.  The ideal
# sum holds products of two scaled values, hence scale 2**(2 * _SHIFT).
_SHIFT = 1074


def _exact(x: float) -> int:
    """``x`` as an integer scaled by ``2**_SHIFT`` (exact for any finite
    float: the denominator of ``as_integer_ratio`` is a power of two no
    larger than ``2**_SHIFT``)."""
    p, q = x.as_integer_ratio()
    return p << (_SHIFT + 1 - q.bit_length())


@dataclasses.dataclass(frozen=True)
class LossRow:
    """One waterfall row: chip-time lost in one (layer, phase) cell."""
    layer: str
    phase: Optional[str]       # None for the unallocated-capacity row
    bucket: str
    chip_time: float
    frac_of_capacity: float

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class AttributionWaterfall:
    """Streaming per-layer/per-phase lost-chip-time attribution.

    Attach to a ledger *before* any event is emitted (like a trace
    recorder) so the mirror accumulators see the identical stream::

        ledger = GoodputLedger(...)
        wf = AttributionWaterfall().attach(ledger)
        ...emit...
        wf.assert_conserves(ledger)       # bit-for-bit + exact partition
        report = wf.report()
    """

    def __init__(self):
        self._ledger: Optional[GoodputLedger] = None
        self.n_events = 0
        # float mirror of the ledger's aggregate accumulator — identical
        # operations in identical order, so totals compare with plain ==
        self._mirror = _Acc()
        # exact per-(layer, phase) chip-time cells (capacity partition),
        # as ints scaled by 2**_SHIFT — see module comment on _exact
        self._cells: Dict[Tuple[str, str], int] = defaultdict(int)
        # exact running totals over the same addends as the cells
        # (allocated/productive at scale 2**_SHIFT, ideal at 2**(2*_SHIFT))
        self._exact_allocated = 0
        self._exact_productive = 0
        self._exact_ideal = 0
        # demand-side waiting time (QUEUED/PARTIAL) per layer — reported,
        # not part of the capacity partition
        self._waits: Dict[Tuple[str, str], int] = defaultdict(int)
        # layer_of memo for the batched path, keyed on interned-segment
        # identity + phase (layer_of falls back to a per-phase default
        # when the segment carries no valid layer tag)
        self._layer_cache: Dict[Tuple[int, str], Tuple[dict, str]] = {}
        # pg floats repeat heavily across a stream; cache their scalings
        self._pg_exact: Dict[float, int] = {}

    # ---- ingestion --------------------------------------------------------
    def attach(self, ledger: GoodputLedger) -> "AttributionWaterfall":
        if ledger.n_events:
            raise ValueError(
                "AttributionWaterfall must attach before any event is "
                "emitted — the ledger already holds events, so the mirror "
                "accumulators could never match ledger.totals()")
        self._ledger = ledger
        ledger.subscribe_events(self.on_event, batch_fn=self.on_batch)
        return self

    def on_event(self, iv: Interval, pg: float) -> None:
        ct = iv.chip_time
        if ct <= 0.0:
            return
        self.n_events += 1
        self._mirror.add(iv.phase, ct, pg)
        layer = layer_of(iv.segment, iv.phase)
        cell = (layer.value, iv.phase.value)
        exact_ct = _exact(ct)
        if iv.phase in ALLOCATED_PHASES:
            self._cells[cell] += exact_ct
            self._exact_allocated += exact_ct
            if iv.phase in PRODUCTIVE_PHASES:
                self._exact_productive += exact_ct
                self._exact_ideal += exact_ct * _exact(pg)
        else:
            self._waits[cell] += exact_ct

    def on_batch(self, batch) -> None:
        """Columnar twin of :meth:`on_event` (the ledger's batched ingest
        delivers an ``IntervalBatch`` here): identical accumulator updates
        in identical order, so the float mirror and the exact cells match
        the per-event path bit-for-bit.  The responsible layer is resolved
        once per interned segment-dict identity, not per event."""
        mirror = self._mirror
        mphase = mirror.phase
        cells = self._cells
        waits = self._waits
        layer_cache = self._layer_cache
        pg_exact = self._pg_exact
        phases = batch.phases
        pgs = batch.pgs
        segments = batch.segments
        cts = batch.chip_times
        n = 0
        ea = ep = ei = 0     # integer sums commute exactly; fold in at end
        for i in range(len(cts)):
            ct = cts[i]
            if ct <= 0.0:
                continue
            n += 1
            ph = phases[i]
            pg = pgs[i]
            seg = segments[i]
            # inlined _Acc.add body — identical float ops, identical order
            pv = ph._value_
            mphase[pv] = mphase.get(pv, 0.0) + ct
            key = (id(seg), pv)
            entry = layer_cache.get(key)
            if entry is not None and entry[0] is seg:
                lv = entry[1]
            else:
                lv = layer_of(seg, ph).value
                if len(layer_cache) < 4096:
                    layer_cache[key] = (seg, lv)
            exact_ct = _exact(ct)
            if ph._x_alloc:
                mirror.allocated += ct
                cells[(lv, pv)] += exact_ct
                ea += exact_ct
                if ph._x_prod:
                    mirror.productive += ct
                    mirror.ideal += ct * pg
                    ep += exact_ct
                    pgx = pg_exact.get(pg)
                    if pgx is None:
                        pgx = pg_exact[pg] = _exact(pg)
                    ei += exact_ct * pgx
            else:
                waits[(lv, pv)] += exact_ct
        self._exact_allocated += ea
        self._exact_productive += ep
        self._exact_ideal += ei
        self.n_events += n

    # ---- conservation -----------------------------------------------------
    @property
    def capacity_chip_time(self) -> float:
        return self._ledger.capacity_chip_time if self._ledger else 0.0

    def conservation(self, capacity_chip_time: Optional[float] = None
                     ) -> Dict[str, bool]:
        """The exactness contract, checked with zero tolerance:

          * ``cells_partition_allocated`` — Σ (layer, phase) cells equals
            allocated chip-time in exact rational arithmetic, so a
            misrouted or dropped event cannot hide in float slack (the
            capacity identity ``ideal + gap + losses + unallocated ==
            capacity`` then holds by construction: gap, losses and
            unallocated are defined as the residuals);
          * ``capacity_covers_allocated`` — a *set* capacity is at least
            the allocated chip-time, so the derived unallocated row is
            non-negative (this is what a mis-set capacity breaks;
            vacuous when no capacity was ever registered, the
            RG-only/orchestrator use);
          * ``mirrors_ledger`` — the float mirror equals
            ``ledger.totals()`` bit-for-bit (plain ``==`` on floats).
        """
        cap = _exact(self.capacity_chip_time
                     if capacity_chip_time is None else capacity_chip_time)
        cells_total = sum(self._cells.values())
        out = {
            "cells_partition_allocated": cells_total == self._exact_allocated,
            "capacity_covers_allocated":
                cap == 0 or cap >= self._exact_allocated,
            "mirrors_ledger": (self._ledger is None
                               or self.totals_match(self._ledger)),
        }
        out["conserved"] = all(out.values())
        return out

    def totals_match(self, ledger: GoodputLedger) -> bool:
        """Bit-for-bit: the float mirror reproduces ``ledger.totals()``."""
        t = ledger.totals()
        return (self.n_events == t["n_events"]
                and self._mirror.allocated == t["allocated_chip_time"]
                and self._mirror.productive == t["productive_chip_time"]
                and self._mirror.ideal == t["ideal_chip_time"]
                and dict(self._mirror.phase) == t["by_phase"])

    def assert_conserves(self, ledger: Optional[GoodputLedger] = None
                         ) -> None:
        ledger = ledger if ledger is not None else self._ledger
        if ledger is not None and not self.totals_match(ledger):
            raise AssertionError(
                "attribution drift: waterfall mirror != ledger.totals()\n"
                f"  mirror: allocated={self._mirror.allocated!r} "
                f"productive={self._mirror.productive!r} "
                f"ideal={self._mirror.ideal!r} n={self.n_events}\n"
                f"  ledger: {ledger.totals()!r}")
        checks = self.conservation()
        bad = [k for k, ok in checks.items() if not ok]
        if bad:
            raise AssertionError(f"attribution conservation failed: {bad}")

    # ---- reporting --------------------------------------------------------
    def lost_chip_time(self, layer: Optional[Layer] = None,
                       phase: Optional[Phase] = None) -> float:
        """Allocated-but-unproductive chip-time, filtered by layer and/or
        phase (waiting time excluded — see module docstring)."""
        total = 0
        for (lyr, ph), ct in self._cells.items():
            if Phase(ph) in PRODUCTIVE_PHASES:
                continue
            if layer is not None and lyr != layer.value:
                continue
            if phase is not None and ph != phase.value:
                continue
            total += ct
        return float(Fraction(total, 1 << _SHIFT))

    def bucket_totals(self) -> Dict[str, float]:
        """Chip-time per named loss bucket, folding the exact (layer,
        phase) cells *and* the demand-side waits by bucket name.  Exact
        integer cells convert to floats identically on every engine, so a
        controller (or the advisor's addressable-loss early-exit) reading
        these deltas stays decision-identical across engines.  Productive
        cells and empty buckets are omitted."""
        one = 1 << _SHIFT
        out: Dict[str, float] = {}
        for cells in (self._cells, self._waits):
            for (lyr, ph), ct in sorted(cells.items()):
                phase = Phase(ph)
                if phase in PRODUCTIVE_PHASES or ct == 0:
                    continue
                bucket = loss_bucket(phase, Layer(lyr))
                out[bucket] = out.get(bucket, 0.0) + float(Fraction(ct, one))
        return out

    def report(self, capacity_chip_time: Optional[float] = None
               ) -> Dict[str, object]:
        """The waterfall, JSON-ready: capacity decomposed into ideal,
        program gap, named per-layer losses (sorted, largest first), and
        unallocated capacity; demand-side waits listed separately."""
        cap = (self.capacity_chip_time if capacity_chip_time is None
               else capacity_chip_time)
        fcap = cap if cap else 1.0
        one = 1 << _SHIFT
        rows: List[LossRow] = []
        for (lyr, ph), ct in sorted(self._cells.items()):
            phase = Phase(ph)
            if phase in PRODUCTIVE_PHASES or ct == 0:
                continue
            fct = float(Fraction(ct, one))
            rows.append(LossRow(layer=lyr, phase=ph,
                                bucket=loss_bucket(phase, Layer(lyr)),
                                chip_time=fct,
                                frac_of_capacity=fct / fcap))
        # productive is at scale 2**_SHIFT, ideal at 2**(2*_SHIFT)
        gap = float(Fraction((self._exact_productive << _SHIFT)
                             - self._exact_ideal, one * one))
        if gap:
            rows.append(LossRow(layer=Layer.MODEL.value, phase="step",
                                bucket="program_gap", chip_time=gap,
                                frac_of_capacity=gap / fcap))
        # the unallocated row only exists relative to a set capacity; on
        # a capacity-less ledger (RG-only use) it would be a meaningless
        # negative residual
        unalloc = (float(Fraction(_exact(cap) - self._exact_allocated, one))
                   if cap else 0.0)
        if unalloc:
            rows.append(LossRow(layer=Layer.SCHEDULING.value, phase=None,
                                bucket="unallocated_capacity",
                                chip_time=unalloc,
                                frac_of_capacity=unalloc / fcap))
        rows.sort(key=lambda r: (-r.chip_time, r.layer, r.bucket))
        by_layer: Dict[str, float] = defaultdict(float)
        for r in rows:
            by_layer[r.layer] += r.chip_time
        return {
            "capacity_chip_time": cap,
            "allocated_chip_time": self._mirror.allocated,
            "productive_chip_time": self._mirror.productive,
            "ideal_chip_time": self._mirror.ideal,
            "losses": [r.as_dict() for r in rows],
            "lost_by_layer": dict(sorted(by_layer.items(),
                                         key=lambda kv: -kv[1])),
            "waits": {f"{lyr}/{ph}": float(Fraction(ct, one))
                      for (lyr, ph), ct in sorted(self._waits.items())
                      if ct},
            "conservation": self.conservation(cap),
        }

    def state_size(self) -> Dict[str, int]:
        """Accumulator entries — bounded by #layers x #phases, not by
        events (the ``benchmarks/ledger_scale.py`` memory story)."""
        return {"cells": len(self._cells), "waits": len(self._waits)}


def waterfall_from_trace(trace) -> Tuple[AttributionWaterfall, GoodputLedger]:
    """Replay a recorded trace under a fresh waterfall; the replayed
    ledger reproduces the trace footer bit-for-bit, so the attribution is
    exactly the one the live run would have produced."""
    from repro.fleet.trace import replay

    ledger = GoodputLedger(capacity_chip_time=trace.capacity_chip_time,
                           window=trace.window, retain_intervals=False)
    wf = AttributionWaterfall().attach(ledger)
    replay(trace, ledger=ledger)
    return wf, ledger
