"""ML Productivity Goodput (paper §4): the metric itself.

    MPG = Scheduling Goodput x Runtime Goodput x Program Goodput

    SG = all-allocated chip-time          / fleet capacity chip-time
    RG = checkpointed productive chip-time / all-allocated chip-time
    PG = ideal (compute-roofline) time    / actual execution time

The accounting is event-based: jobs emit intervals tagged with a phase
(the paper's Figure 5/11 timeline) and the metric is computed by summing
chip-time per phase.  Work done between the last checkpoint and a failure
or preemption is NOT productive (paper §4.3, Runtime Goodput definition).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterable, Optional


class Phase(enum.Enum):
    """What a job's chips were doing during an interval."""
    QUEUED = "queued"                # waiting for all-allocation (counts against SG)
    PARTIAL = "partial"              # some but not all chips allocated (SG loss)
    INIT = "init"                    # program load/compile/connect (RG loss)
    STEP = "step"                    # productive compute (subject to checkpoint survival)
    CHECKPOINT = "checkpoint"        # synchronous checkpoint write (RG loss)
    DATA_STALL = "data_stall"        # input-pipeline stall (RG loss)
    LOST = "lost"                    # rolled-back work after failure/preemption
    IDLE = "idle"                    # allocated but idle (RG loss)
    SLO_BREACH = "slo_breach"        # serving: decode past the latency SLO
                                     # (allocated, compute ran, but the token
                                     # missed its deadline — an RG loss the
                                     # batching/admission policy is
                                     # responsible for)
    RESHARD = "reshard"              # elastic resize: moving checkpointed
                                     # shards between the old and new
                                     # partition assignments (RG loss)
    CONTROL = "control"              # adaptive-controller overhead: the
                                     # orchestration cost of a live policy
                                     # switch, charged to the scheduling
                                     # layer so closing the loop is itself
                                     # visible in the waterfall (RG loss)


class Layer(enum.Enum):
    """Which stack layer is *responsible* for an interval (paper §3/§6).

    The paper's central diagnostic move is attributing lost goodput to a
    layer of the ML system stack, not just to a timeline phase: the same
    LOST second is a hardware problem after a chip failure but a
    scheduling problem after a preemption.  Every emitter
    (``fleet.sim`` / ``runtime.orchestrator`` / ``launch.serve``) tags
    its intervals with the responsible layer via ``segment["layer"]``;
    the emitting subsystem itself is tagged separately as
    ``segment["emitter"]`` (fleet / runtime / serve — trace provenance).
    """
    MODEL = "model"                  # the program's own compute
    DATA = "data"                    # input pipeline
    FRAMEWORK = "framework"          # runtime/framework (ckpt, multi-client)
    COMPILER = "compiler"            # JIT/AOT compilation
    SCHEDULING = "scheduling"        # placement, preemption, batching
    HARDWARE = "hardware"            # failures, slow generations


# the layer held responsible for a phase when the emitter did not say
# (legacy streams, hand-built test intervals)
DEFAULT_LAYER: Dict[Phase, Layer] = {
    Phase.QUEUED: Layer.SCHEDULING,
    Phase.PARTIAL: Layer.SCHEDULING,
    Phase.INIT: Layer.FRAMEWORK,
    Phase.STEP: Layer.MODEL,
    Phase.CHECKPOINT: Layer.FRAMEWORK,
    Phase.DATA_STALL: Layer.DATA,
    Phase.LOST: Layer.HARDWARE,
    Phase.IDLE: Layer.SCHEDULING,
    Phase.SLO_BREACH: Layer.SCHEDULING,
    Phase.RESHARD: Layer.SCHEDULING,
    Phase.CONTROL: Layer.SCHEDULING,
}

# (Phase, Layer) -> named loss bucket: the rows of the attribution
# waterfall (repro.core.attribution).  One phase splits into different
# buckets by responsible layer — LOST is a failure rollback on the
# hardware layer but a preemption rollback on the scheduling layer.
LOSS_BUCKETS: Dict[tuple, str] = {
    (Phase.QUEUED, Layer.SCHEDULING): "queue_wait",
    (Phase.PARTIAL, Layer.SCHEDULING): "allocation_wait",
    (Phase.INIT, Layer.COMPILER): "compile",
    (Phase.INIT, Layer.FRAMEWORK): "program_setup",
    (Phase.INIT, Layer.SCHEDULING): "migration_restart",
    (Phase.INIT, Layer.MODEL): "warmup",
    (Phase.CHECKPOINT, Layer.FRAMEWORK): "checkpoint_write",
    (Phase.DATA_STALL, Layer.DATA): "input_stall",
    (Phase.LOST, Layer.HARDWARE): "failure_rollback",
    (Phase.LOST, Layer.SCHEDULING): "preemption_rollback",
    (Phase.IDLE, Layer.SCHEDULING): "batch_bubble",
    (Phase.IDLE, Layer.FRAMEWORK): "host_idle",
    # healthy gang slices holding their allocation while a rigid job
    # waits for a replacement slice after a hardware failure
    (Phase.IDLE, Layer.HARDWARE): "gang_stall",
    (Phase.SLO_BREACH, Layer.SCHEDULING): "slo_breach",
    (Phase.RESHARD, Layer.SCHEDULING): "reshard_transfer",
    (Phase.CONTROL, Layer.SCHEDULING): "policy_switch",
}


def layer_of(segment: Dict[str, str], phase: Phase) -> Layer:
    """The responsible layer of an interval: its ``segment["layer"]`` tag
    when present and valid, else the phase's default layer."""
    tag = segment.get("layer")
    if tag is not None:
        try:
            return Layer(tag)
        except ValueError:
            pass                      # legacy emitter tags ("fleet", ...)
    return DEFAULT_LAYER[phase]


def loss_bucket(phase: Phase, layer: Layer) -> Optional[str]:
    """Waterfall bucket for a (phase, layer) cell; ``None`` for STEP
    (productive time is not a loss).  Unmapped combinations fall back to
    the phase's default-layer bucket name, so arbitrary streams still
    land in a named bucket."""
    if phase in PRODUCTIVE_PHASES:
        return None
    return LOSS_BUCKETS.get((phase, layer),
                            LOSS_BUCKETS[(phase, DEFAULT_LAYER[phase])])


@dataclasses.dataclass(frozen=True)
class Interval:
    """A [t0, t1) span of one job on `chips` chips."""
    job_id: str
    phase: Phase
    t0: float
    t1: float
    chips: int
    segment: Dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def chip_time(self) -> float:
        return max(0.0, self.t1 - self.t0) * self.chips


ALLOCATED_PHASES = {Phase.INIT, Phase.STEP, Phase.CHECKPOINT,
                    Phase.DATA_STALL, Phase.LOST, Phase.IDLE,
                    Phase.SLO_BREACH, Phase.RESHARD, Phase.CONTROL}
PRODUCTIVE_PHASES = {Phase.STEP}


@dataclasses.dataclass
class GoodputReport:
    sg: float
    rg: float
    pg: float
    capacity_chip_time: float
    allocated_chip_time: float
    productive_chip_time: float
    ideal_chip_time: float

    @property
    def mpg(self) -> float:
        return self.sg * self.rg * self.pg

    def as_dict(self) -> Dict[str, float]:
        return {"SG": self.sg, "RG": self.rg, "PG": self.pg, "MPG": self.mpg}


def _ledger_over(intervals: Iterable[Interval],
                 pg_by_job: Optional[Dict[str, float]] = None):
    """Feed an interval stream into a throwaway streaming ledger.

    The batch API is kept as a compatibility veneer; the single source of
    accounting truth is ``repro.core.ledger.GoodputLedger`` (imported
    lazily — ledger.py imports this module's types at load time).
    """
    from repro.core.ledger import GoodputLedger

    led = GoodputLedger(retain_intervals=False, window=0.0)
    led.extend(intervals, pg_by_job=pg_by_job)
    return led


def compute_goodput(intervals: Iterable[Interval],
                    capacity_chip_time: float,
                    pg_by_job: Optional[Dict[str, float]] = None
                    ) -> GoodputReport:
    """Compose MPG from an interval log.

    ``pg_by_job`` maps job -> Program Goodput (ideal/actual step time, from
    the roofline model or measured step times); productive chip-time is
    weighted by it to yield the fleet PG.
    """
    return _ledger_over(intervals, pg_by_job).report(capacity_chip_time)


# ---------------------------------------------------------------------------
# Segmentation (paper §5: disaggregate to find bottlenecks; avoids
# Simpson's-paradox traps by keeping per-segment denominators)
# ---------------------------------------------------------------------------

def segment_goodput(intervals: Iterable[Interval],
                    key: str,
                    capacity_by_segment: Dict[str, float],
                    pg_by_job: Optional[Dict[str, float]] = None
                    ) -> Dict[str, GoodputReport]:
    """Per-segment MPG, segmenting on an interval tag (e.g. 'phase_kind',
    'arch', 'size_class', 'framework', 'chip')."""
    tagged = (iv if key in iv.segment else
              dataclasses.replace(iv, segment={**iv.segment, key: "unknown"})
              for iv in intervals)
    return _ledger_over(tagged, pg_by_job).segment_report(key,
                                                          capacity_by_segment)


def rg_breakdown(intervals: Iterable[Interval]) -> Dict[str, float]:
    """Where allocated-but-unproductive chip-time goes (paper Fig. 10)."""
    return _ledger_over(intervals).rg_breakdown()


# ---------------------------------------------------------------------------
# Heterogeneous hardware generations (paper §3.1: the fleet mixes TPU
# generations; PG normalizes productive time against peak FLOPS)
# ---------------------------------------------------------------------------

def generation_pg_weights(generations: Iterable[str]) -> Dict[str, float]:
    """Per-generation PG weights from peak-FLOPS ratios.

    Ideal chip-time is defined against the *best* generation present, so
    a STEP second on a slower generation contributes proportionally less
    ideal time: weight = peak_flops(gen) / max peak_flops over the given
    generations.  All weights land in (0, 1], keeping PG <= 1.
    """
    from repro.core.hardware import GENERATIONS

    gens = sorted(set(generations))
    unknown = [g for g in gens if g not in GENERATIONS]
    if unknown:
        raise ValueError(f"unknown hardware generation(s) {unknown}; "
                         f"choose from {sorted(GENERATIONS)}")
    if not gens:
        return {}
    best = max(GENERATIONS[g].peak_flops_bf16 for g in gens)
    return {g: GENERATIONS[g].peak_flops_bf16 / best for g in gens}
