"""Three-term roofline analysis from compiled dry-run artifacts (deliverable g).

    compute term    = HLO_FLOPs   / (chips * peak_FLOP/s)
    memory term     = HLO_bytes   / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs/bytes come from a *cost-reference compile* (single device,
loops unrolled) because ``cost_analysis()`` counts while bodies once
(verified empirically; see EXPERIMENTS.md §Dry-run).  Costs that exceed
feasible reference sizes are recovered by exact polynomial extrapolation in
batch/seq (matmul cost is linear in batch, attention quadratic in seq — a
degree-2 fit is exact, not an approximation).  Collective bytes come from
the SPMD-partitioned HLO of the real 256/512-chip compile, with while-loop
trip-count multiplication (repro.core.hlo_analysis); the parsed program is
per-device, so the chips factor cancels:  t_coll = parsed_bytes / link_bw.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.hardware import ChipSpec, TPU_V5E
from repro.core.flops import model_flops
from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float           # total, all chips
    hlo_bytes: float           # total, all chips
    collective_bytes_per_chip: float
    model_flops: float
    chip: ChipSpec = TPU_V5E

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * self.chip.peak_flops_bf16)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * self.chip.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / self.chip.ici_link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_ideal(self) -> float:
        """Paper PG numerator: MODEL_FLOPS at peak."""
        return self.model_flops / (self.chips * self.chip.peak_flops_bf16)

    @property
    def t_lower_bound(self) -> float:
        """Best case: perfect compute/memory/collective overlap."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def t_no_overlap(self) -> float:
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: fraction of compiled compute that is
        'useful' (catches remat recompute, masked-attention waste, dispatch
        overhead)."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def pg_optimistic(self) -> float:
        return self.t_ideal / self.t_lower_bound if self.t_lower_bound else 0.0

    @property
    def pg_pessimistic(self) -> float:
        return self.t_ideal / self.t_no_overlap if self.t_no_overlap else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_ratio,
            "pg_overlap": self.pg_optimistic,
            "pg_no_overlap": self.pg_pessimistic,
        }


def make_cell(cfg: ModelConfig, shape: ShapeConfig, mesh_name: str,
              chips: int, hlo_flops: float, hlo_bytes: float,
              collective_bytes_per_chip: float) -> RooflineCell:
    return RooflineCell(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        collective_bytes_per_chip=collective_bytes_per_chip,
        model_flops=model_flops(cfg, shape))


def fit_poly_and_eval(xs, ys, x_target: float, degree: int = 2) -> float:
    """Exact polynomial cost extrapolation (costs are polynomial in
    batch/seq by construction)."""
    import numpy as np

    degree = min(degree, len(xs) - 1)
    coef = np.polyfit(np.asarray(xs, float), np.asarray(ys, float), degree)
    return float(np.polyval(coef, x_target))
