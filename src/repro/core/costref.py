"""Cost-reference compiles: honest HLO_FLOPs / HLO_bytes for the roofline.

``compiled.cost_analysis()`` counts while-loop bodies once, so the sharded
production compile (scan over layers, scan over attention chunks)
undercounts by ~num_layers.  This module compiles a *single-device,
fully-unrolled* variant of each cell at reduced batch/seq and recovers the
full-size cost by exact polynomial extrapolation:

  * cost is exactly LINEAR in global batch (samples are independent)
    -> two batch points give the slope and the batch-independent constant
      (parameter/optimizer work, weight reads);
  * cost is exactly QUADRATIC in seq for full attention and LINEAR beyond
    the window for SWA -> a degree-2 fit over >= 3 seq points is exact;
  * cost is (empirically exactly) QUADRATIC in the layer count — a small
    superlinear term appears in XLA's accounting — so three layer points
    ({2, 4, 6}, or one pattern-period multiples for hybrids) with a
    degree-2 fit reproduce a direct 30-layer compile to 0.002% (flops) /
    0.02% (bytes); an 80-layer reference never has to be unrolled.

Results are cached in results/costref/ (keyed by arch/shape/knobs) because
reference compiles take minutes for the big configs.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
from typing import Dict, List, Optional, Tuple

import jax

from repro.core.roofline import fit_poly_and_eval
from repro.models import model
from repro.models.config import ModelConfig, ShapeConfig

CACHE_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "costref"

# Above this estimated unrolled-op budget we shrink seq and extrapolate.
_MAX_DIRECT_SEQ = 8192


def _unrolled(cfg: ModelConfig, n_layers: Optional[int] = None) -> ModelConfig:
    kw = dict(scan_layers=False, unroll_loops=True)
    if n_layers is not None:
        kw["num_layers"] = n_layers
        if cfg.family == "encdec":
            kw["encoder_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def _layer_points(cfg: ModelConfig) -> List[int]:
    """Layer counts for the reference compiles (linear-in-L extrapolation)."""
    if cfg.family == "hybrid" and cfg.attn_every > 1:
        pts = [cfg.attn_every * k for k in (1, 2, 3)]
    elif cfg.first_k_dense > 0:
        pts = [cfg.first_k_dense + k for k in (2, 4, 6)]
    else:
        pts = [2, 4, 6]
    if cfg.num_layers <= pts[-1]:
        return [cfg.num_layers]
    return pts


def _compile_cost(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[float, float]:
    """Single-device lower+compile; returns (flops, bytes)."""
    from repro.launch.strategy import (abstract_train_state, make_train_step)
    from repro.optim import AdamWConfig

    if shape.kind == "train":
        fn = make_train_step(cfg, AdamWConfig())
        args = (abstract_train_state(cfg), model.input_specs(cfg, shape))
    elif shape.kind == "prefill":
        pfn = model.prefill_fn(cfg)
        fn = lambda p, b: pfn(p, b)  # noqa: E731
        args = (model.abstract_params(cfg), model.input_specs(cfg, shape))
    else:
        dfn = model.decode_fn(cfg)
        fn = lambda p, t, c: dfn(p, t, c)  # noqa: E731
        specs = model.input_specs(cfg, shape)
        args = (model.abstract_params(cfg), specs["token"], specs["cache"])
    compiled = jax.jit(fn).lower(*args).compile()
    cost = compiled.cost_analysis()
    return float(cost.get("flops", 0.0)), float(cost.get("bytes accessed", 0.0))


def _seq_points(cfg: ModelConfig, shape: ShapeConfig) -> List[int]:
    """Seq sizes for the reference compiles (>= window + chunk for SWA)."""
    target = shape.seq_len
    if shape.kind == "decode":
        # decode cost is linear in cache depth; the graph is tiny, so
        # compile at the real depth directly.
        return [target]
    if target <= _MAX_DIRECT_SEQ:
        return [target]
    floor = (cfg.attention_window + cfg.attn_chunk + cfg.attn_chunk
             if cfg.attention_window else 2 * cfg.attn_chunk)
    base = max(floor, 2048)
    pts = [base, base + 2048, base + 4096]
    return [min(p, target) for p in pts]


def _batch_points(shape: ShapeConfig) -> List[int]:
    return [1] if shape.global_batch == 1 else [1, 2]


def _cache_key(cfg: ModelConfig, shape: ShapeConfig) -> str:
    blob = json.dumps({
        "arch": cfg.name, "shape": shape.name,
        "layers": cfg.num_layers, "d": cfg.d_model, "ff": cfg.d_ff,
        "vocab": cfg.vocab_size, "chunk": cfg.attn_chunk,
        "remat": cfg.remat, "window": cfg.attention_window,
        "experts": cfg.num_experts, "moe_impl": cfg.moe_impl,
        "v": 5,
    }, sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def cost_reference(cfg: ModelConfig, shape: ShapeConfig,
                   use_cache: bool = True) -> Dict[str, float]:
    """Extrapolated full-size (flops, bytes) for one assignment cell."""
    CACHE_DIR.mkdir(parents=True, exist_ok=True)
    cache_file = CACHE_DIR / f"{cfg.name}__{shape.name}__{_cache_key(cfg, shape)}.json"
    if use_cache and cache_file.exists():
        return json.loads(cache_file.read_text())

    seqs = _seq_points(cfg, shape)
    batches = _batch_points(shape)
    layer_pts = _layer_points(cfg)

    # grid of small reference compiles: (layers, seq, batch)
    grid: Dict[Tuple[int, int, int], Tuple[float, float]] = {}
    for lp in layer_pts:
        ucfg = _unrolled(cfg, lp)
        for s in seqs:
            for b in batches:
                sub = ShapeConfig(shape.name, shape.kind, s, b)
                grid[(lp, s, b)] = _compile_cost(ucfg, sub)

    target_layers = cfg.num_layers

    def at_layers(s: int, b: int, idx: int) -> float:
        """Degree-2 fit over layer points (exact; see module docstring)."""
        if len(layer_pts) == 1:
            return grid[(layer_pts[0], s, b)][idx]
        return fit_poly_and_eval(layer_pts,
                                 [grid[(lp, s, b)][idx] for lp in layer_pts],
                                 target_layers)

    def at_batch(s: int, target_b: int, idx: int) -> float:
        if len(batches) == 1:
            return at_layers(s, batches[0], idx) * target_b
        c1 = at_layers(s, batches[0], idx)
        c2 = at_layers(s, batches[1], idx)
        slope = (c2 - c1) / (batches[1] - batches[0])
        return (c1 - slope * batches[0]) + slope * target_b

    tb = shape.global_batch
    if len(seqs) == 1:
        flops = at_batch(seqs[0], tb, 0)
        bytes_ = at_batch(seqs[0], tb, 1)
    else:
        flops = fit_poly_and_eval(seqs, [at_batch(s, tb, 0) for s in seqs],
                                  shape.seq_len)
        bytes_ = fit_poly_and_eval(seqs, [at_batch(s, tb, 1) for s in seqs],
                                   shape.seq_len)

    out = {
        "arch": cfg.name, "shape": shape.name,
        "flops": flops, "bytes": bytes_,
        "ref_points": {f"l{lp}_s{s}_b{b}": grid[(lp, s, b)]
                       for lp in layer_pts for s in seqs for b in batches},
    }
    cache_file.write_text(json.dumps(out, indent=1))
    return out
