"""Input pipeline: synthetic token stream with background prefetch and
Plumber-style bottleneck analysis (paper §5.2, ref [36]).

The pipeline is a chain of named stages (generate -> tokenize-stub ->
batch -> shard).  A background thread keeps a bounded prefetch queue warm;
per-stage wall-times are recorded so `analyze()` can report which stage
bounds throughput and by how much — exactly what Plumber does for tf.data.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class PipelineStats:
    stage_time_s: Dict[str, float]
    batches: int
    consumer_wait_s: float
    producer_idle_s: float

    def bottleneck(self) -> Tuple[str, float]:
        """(stage, fraction of total pipeline time)."""
        total = sum(self.stage_time_s.values()) or 1.0
        name = max(self.stage_time_s, key=self.stage_time_s.get)
        return name, self.stage_time_s[name] / total

    def input_bound(self) -> bool:
        """True when the model waits on data (RG loss; paper Fig. 10)."""
        return self.consumer_wait_s > self.producer_idle_s


class DataPipeline:
    """Synthetic causal-LM batches: tokens (batch, seq) int32."""

    def __init__(self, vocab_size: int, batch: int, seq: int,
                 seed: int = 0, prefetch: int = 2,
                 extra_stage_cost_s: float = 0.0,
                 extra_fields: Optional[Dict[str, Tuple[tuple, Any]]] = None):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq
        self.prefetch = prefetch
        self.extra_cost = extra_stage_cost_s
        self.extra_fields = extra_fields or {}
        self._rng = np.random.default_rng(seed)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stats = {"generate": 0.0, "augment": 0.0, "shard": 0.0}
        self._consumer_wait = 0.0
        self._producer_idle = 0.0
        self._batches = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ---- stages -----------------------------------------------------------
    def _generate(self) -> Dict[str, np.ndarray]:
        t0 = time.monotonic()
        out = {"tokens": self._rng.integers(
            0, self.vocab, (self.batch, self.seq), dtype=np.int32)}
        for name, (shape, dtype) in self.extra_fields.items():
            out[name] = np.zeros((self.batch, *shape), dtype)
        self._stats["generate"] += time.monotonic() - t0
        return out

    def _augment(self, b):
        t0 = time.monotonic()
        if self.extra_cost:
            time.sleep(self.extra_cost)   # models an expensive transform
        self._stats["augment"] += time.monotonic() - t0
        return b

    def _shard(self, b):
        t0 = time.monotonic()
        # host-side layout pass (device placement happens in the step fn)
        out = {k: np.ascontiguousarray(v) for k, v in b.items()}
        self._stats["shard"] += time.monotonic() - t0
        return out

    # ---- prefetch loop ------------------------------------------------------
    def _producer(self):
        while not self._stop.is_set():
            item = self._shard(self._augment(self._generate()))
            t0 = time.monotonic()
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            self._producer_idle += time.monotonic() - t0

    def start(self):
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self):
        if self._thread is None:    # synchronous mode
            self._batches += 1
            return self._shard(self._augment(self._generate()))
        t0 = time.monotonic()
        item = self._q.get()
        self._consumer_wait += time.monotonic() - t0
        self._batches += 1
        return item

    # ---- plumber ------------------------------------------------------------
    def analyze(self) -> PipelineStats:
        return PipelineStats(
            stage_time_s=dict(self._stats),
            batches=self._batches,
            consumer_wait_s=self._consumer_wait,
            producer_idle_s=self._producer_idle,
        )
