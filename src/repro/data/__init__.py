from repro.data.pipeline import DataPipeline, PipelineStats  # noqa: F401
