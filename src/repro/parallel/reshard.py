"""Elastic-reshard cost model: bytes moved between partition assignments.

When an elastic job resizes (a gang slice dies, or a degraded job regrows
to its submitted width), its checkpointed state must be re-partitioned:
every chip has to fetch the part of its *new* shard it does not already
hold.  This module prices that movement from the same logical-axis ->
mesh-axis rule walk ``repro.parallel.sharding`` uses to place parameters
(``assign_axes`` below is that walk, extracted so the fleet simulator can
run it without jax), over the real per-architecture parameter inventories
(shapes + logical axes + dtype sizes from ``repro.models.init.spec_tree``).

The module is deliberately jax-free: the fleet engines and the numpy-only
CI smokes price resharding from the committed ``param_inventory.json``
(regenerate with ``python -m repro.parallel.reshard --refresh-inventory``,
which needs jax; a tier-1 test pins the committed file against a fresh
derivation so it cannot rot).

Cost model (documented, deliberately simple):
  * canonical mesh for a slice of C chips: model = min(8, largest power
    of two dividing C), data = C / model — the TP-within-FSDP default
    the launcher uses;
  * a leaf replicated under the *old* mesh is free to reshard (every
    chip already holds all of it);
  * any other leaf costs its full new per-chip shard: the chip gathers
    its new shard from peers / the checkpoint over DCN;
  * optimizer state travels with the parameters
    (``OPT_STATE_FACTOR`` = params + Adam m + v);
  * transfers run chip-parallel over per-chip DCN bandwidth
    (``repro.core.hardware.DCN_BW_PER_CHIP``).
"""
from __future__ import annotations

import functools
import json
import math
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.hardware import DCN_BW_PER_CHIP

# logical axis -> candidate mesh axes (first that divides wins; () =
# replicate).  This is THE rule table — repro.parallel.sharding re-exports
# it and builds jax PartitionSpecs from the same walk.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "vocab": ("model",),
    "embed": ("data",),          # FSDP/ZeRO: weights gathered per-layer
    "ffn": ("model",),           # TP
    "heads": ("model",),
    "kv": ("model",),
    "experts": ("model",),       # EP when num_experts % model == 0
    "experts_r": (),             # router output dim: tiny, replicate
    "rnn": ("model",),
    "rnn_in": ("data",),
    "pos": (),
    "layers": (),
    "vec": (),
    "embed_v": (),
    "vec2": (),
}

# params + Adam first/second moments move together on a resize
OPT_STATE_FACTOR = 3.0

_INVENTORY_PATH = pathlib.Path(__file__).parent / "param_inventory.json"


def assign_axes(shape: Sequence[int], axes: Sequence[str],
                mesh_axes: Dict[str, int],
                rules: Optional[Dict[str, Tuple[str, ...]]] = None
                ) -> Tuple[Optional[str], ...]:
    """Per-dim mesh-axis assignment for one parameter: the first rule
    candidate present in the mesh, not already used by another dim, and
    dividing the dim evenly wins; otherwise the dim replicates.

    ``mesh_axes`` maps mesh axis name -> size (insertion order is the
    mesh's axis order).  This is the exact walk
    ``sharding.spec_to_pspec`` wraps in a jax ``PartitionSpec``.
    """
    rules = rules or DEFAULT_RULES
    parts: List[Optional[str]] = []
    used = set()
    for dim, logical in zip(shape, axes):
        choice = None
        for cand in rules.get(logical, ()):
            size = mesh_axes.get(cand, 1)
            if cand in mesh_axes and cand not in used \
                    and dim % size == 0 and size > 1:
                choice = cand
                break
        if choice:
            used.add(choice)
        parts.append(choice)
    return tuple(parts)


def canonical_mesh(chips: int) -> Dict[str, int]:
    """The launcher's default TP-within-FSDP mesh for a slice of
    ``chips``: model = min(8, largest power of two dividing chips)."""
    if chips < 1:
        raise ValueError(f"chips must be >= 1, got {chips}")
    pow2 = chips & -chips                   # largest power of 2 dividing
    model = min(8, pow2)
    return {"data": chips // model, "model": model}


# ---------------------------------------------------------------------------
# parameter inventories (shapes + logical axes + dtype sizes per arch)
# ---------------------------------------------------------------------------

def _live_inventory(arch: str) -> List[Tuple[Tuple[int, ...],
                                             Tuple[str, ...], int]]:
    """Derive the inventory from the model registry (requires jax)."""
    import jax

    from repro.configs import get_config
    from repro.models.init import ParamSpec, spec_tree

    leaves = jax.tree.leaves(spec_tree(get_config(arch)),
                             is_leaf=lambda x: isinstance(x, ParamSpec))
    return [(tuple(s.shape), tuple(s.axes),
             jax.dtypes.canonicalize_dtype(s.dtype).itemsize)
            for s in leaves]


@functools.lru_cache(maxsize=None)
def param_inventory(arch: str) -> List[Tuple[Tuple[int, ...],
                                             Tuple[str, ...], int]]:
    """(shape, logical axes, dtype itemsize) per parameter leaf, from the
    committed JSON when present (jax-free path), else derived live."""
    if _INVENTORY_PATH.exists():
        table = json.loads(_INVENTORY_PATH.read_text())
        if arch in table:
            return [(tuple(shape), tuple(axes), itemsize)
                    for shape, axes, itemsize in table[arch]]
    return _live_inventory(arch)


# ---------------------------------------------------------------------------
# the cost itself
# ---------------------------------------------------------------------------

def _shard_bytes_per_chip(shape, itemsize, parts, mesh: Dict[str, int]
                          ) -> float:
    elems = math.prod(shape)
    for dim, part in zip(shape, parts):
        if part:
            elems //= mesh[part]
    return float(elems * itemsize)


@functools.lru_cache(maxsize=None)
def reshard_bytes_per_chip(arch: str, old_chips: int, new_chips: int
                           ) -> float:
    """Inbound bytes per chip to re-partition ``arch`` parameters from a
    slice of ``old_chips`` to one of ``new_chips`` (optimizer state
    included)."""
    old_mesh = canonical_mesh(old_chips)
    new_mesh = canonical_mesh(new_chips)
    inbound = 0.0
    for shape, axes, itemsize in param_inventory(arch):
        old_parts = assign_axes(shape, axes, old_mesh)
        if not any(old_parts):
            continue                 # replicated before: already on-chip
        new_parts = assign_axes(shape, axes, new_mesh)
        inbound += _shard_bytes_per_chip(shape, itemsize, new_parts,
                                         new_mesh)
    return inbound * OPT_STATE_FACTOR


def reshard_seconds(arch: str, old_chips: int, new_chips: int,
                    bw: float = DCN_BW_PER_CHIP) -> float:
    """Wall seconds the resize transfer takes (chip-parallel over DCN)."""
    if old_chips == new_chips:
        return 0.0
    return reshard_bytes_per_chip(arch, old_chips, new_chips) / bw


# ---------------------------------------------------------------------------
# inventory refresh CLI (requires jax + the model registry)
# ---------------------------------------------------------------------------

def refresh_inventory(path: pathlib.Path = _INVENTORY_PATH) -> dict:
    from repro.configs import ARCH_IDS

    table = {arch: [[list(shape), list(axes), itemsize]
                    for shape, axes, itemsize in _live_inventory(arch)]
             for arch in ARCH_IDS}
    path.write_text(json.dumps(table, indent=1, sort_keys=True) + "\n")
    return table


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--refresh-inventory", action="store_true",
                    help="rederive param_inventory.json from the model "
                         "registry (requires jax)")
    args = ap.parse_args()
    if args.refresh_inventory:
        table = refresh_inventory()
        print(f"wrote {_INVENTORY_PATH} "
              f"({len(table)} archs, "
              f"{sum(len(v) for v in table.values())} leaves)")
    else:
        for arch in sorted(json.loads(_INVENTORY_PATH.read_text())
                           if _INVENTORY_PATH.exists() else []):
            print(f"{arch}: 64->32 chips "
                  f"{reshard_seconds(arch, 64, 32):.3f}s, "
                  f"32->64 chips {reshard_seconds(arch, 32, 64):.3f}s")
