"""Decomposed collective-matmul (Wang et al. [66], paper §5.1): overlap
communication with dependent computation.

A TP matmul  y = x @ W  with x sharded over `axis` on its contraction-free
dim normally lowers to  all-gather(x) -> dot.  The decomposition instead
runs a ring: at each of the N steps, compute the partial dot for the shard
currently held while collective-permuting the next shard — the transfer of
chunk i+1 hides behind the matmul of chunk i.  On TPU the ICI ring makes
this latency-optimal; XLA's own async all-gather achieves partial overlap,
and this manual schedule is the structural ceiling (the paper's reported
1.38x throughput / 72% FLOPS-util on 1024 chips for a 500B model).

``ring_allgather_matmul`` is numerically identical to the plain lowering
(tests assert allclose); the roofline benchmark measures exposed vs hidden
collective bytes in the compiled HLO.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def ring_allgather_matmul(x, w, mesh: Mesh, axis: str = "model"):
    """y = x @ w — classic collective matmul.

    x: (m, k) row-sharded P(axis, None); w: (k, n) column-sharded
    P(None, axis).  The plain lowering all-gathers x, then dots with the
    local w column block.  Here, each device instead walks the ring: at step
    i it dots the x block it currently holds (filling those output rows)
    while collective-permuting the block onward — the transfer of block i+1
    hides behind the matmul of block i.  Per-device compute is identical to
    the plain lowering (m x k x n/n_dev); only the gather is decomposed.

    Returns (m, n) with columns sharded over `axis`.
    """
    n_dev = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def local(xs, wl):
        # xs: (m/n_dev, k) this device's row block; wl: (k, n/n_dev)
        idx = jax.lax.axis_index(axis)
        fwd = [(i, (i + 1) % n_dev) for i in range(n_dev)]
        m_loc = xs.shape[0]

        def step(i, carry):
            block, acc = carry
            part = jnp.einsum("mk,kn->mn", block, wl,
                              preferred_element_type=jnp.float32
                              ).astype(block.dtype)
            src = (idx - i) % n_dev     # owner of the block just consumed
            acc = jax.lax.dynamic_update_slice_in_dim(
                acc, part, src * m_loc, axis=0)
            block = jax.lax.ppermute(block, axis, fwd)
            return block, acc

        acc0 = jnp.zeros((m_loc * n_dev, wl.shape[1]), xs.dtype)
        _, acc = jax.lax.fori_loop(0, n_dev, step, (xs, acc0))
        return acc

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(None, axis)),
        out_specs=P(None, axis),
        check_vma=False,
    )(x, w)


def plain_allgather_matmul(x, w, mesh: Mesh, axis: str = "model"):
    """Reference lowering: blocking all-gather(x) then dot with local w."""
    xs = jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(axis, None)))
    ws = jax.lax.with_sharding_constraint(w, NamedSharding(mesh, P(None, axis)))
    y = jnp.einsum("mk,kn->mn", xs, ws.astype(xs.dtype),
                   preferred_element_type=jnp.float32).astype(xs.dtype)
    return jax.lax.with_sharding_constraint(y, NamedSharding(mesh, P(None, axis)))
