"""Ambient parallelism context.

Model code is mesh-agnostic: it calls :func:`shard_activation` with a logical
activation kind; the launcher installs a :class:`ParallelCtx` that maps kinds
to PartitionSpecs for the active mesh.  Without a context every call is a
no-op, so unit tests and single-device smoke tests never touch device state.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


class ParallelCtx:
    """Maps logical activation kinds -> PartitionSpec on a concrete mesh.

    dp_axes: mesh axes carrying the batch dim (e.g. ("pod", "data")).
    sp_axis: mesh axis carrying the sequence dim between blocks (Megatron
             sequence parallelism), or None.
    tp_axis: tensor-parallel axis (heads / ffn / vocab).
    """

    def __init__(self, mesh: Mesh, dp_axes=("data",), tp_axis="model",
                 sp_axis: Optional[str] = None, bf16_grad: bool = False):
        self.mesh = mesh
        self.dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
        self.tp_axis = tp_axis if tp_axis in mesh.axis_names else None
        self.sp_axis = sp_axis if (sp_axis and sp_axis in mesh.axis_names) else None
        self.bf16_grad = bf16_grad

    def spec(self, kind: str) -> P:
        dp = self.dp_axes if len(self.dp_axes) > 1 else (
            self.dp_axes[0] if self.dp_axes else None)
        if kind == "tokens":          # (b, s)
            return P(dp, self.sp_axis)
        if kind == "act":             # (b, s, d) residual stream
            return P(dp, self.sp_axis, None)
        if kind == "act_heads":       # (b, s, h, hd)
            return P(dp, None, self.tp_axis, None)
        if kind == "logits":          # (b, s, vocab) — vocab TP-sharded
            return P(dp, None, self.tp_axis)
        if kind == "cache":           # (b, S, hkv, hd) — seq-sharded KV cache
            return P(dp, self.tp_axis, None, None)
        if kind == "cache_batch":     # (b, S, hkv, hd) — batch-only sharding
            return P(dp, None, None, None)
        if kind == "kv_rep":          # (b, s, hkv, hd) K/V replicated over tp
            return P(dp, None, None, None)
        if kind == "act_rnn":         # (b, s, rnn_ch) — channel-sharded scan
            return P(dp, None, self.tp_axis)
        raise KeyError(kind)


def set_ctx(ctx: Optional[ParallelCtx]):
    _STATE.ctx = ctx


def get_ctx() -> Optional[ParallelCtx]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def parallel_ctx(ctx: Optional[ParallelCtx]):
    prev = get_ctx()
    set_ctx(ctx)
    try:
        yield ctx
    finally:
        set_ctx(prev)


def shard_activation(x, kind: str):
    """Apply a sharding constraint when a ParallelCtx is installed."""
    ctx = get_ctx()
    if ctx is None:
        return x
    spec = ctx.spec(kind)
    if len(spec) > x.ndim:
        spec = P(*spec[: x.ndim])
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
