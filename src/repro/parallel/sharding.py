"""Logical-axis -> mesh-axis sharding rules.

Every parameter carries logical axis names (repro.models.init.ParamSpec).
A rule table maps logical names to mesh axes; any assignment that does not
divide evenly falls back to replication for that dim (uneven shards are a
perf cliff on TPU, not a correctness feature we want silently).

Default rules implement FSDP ("embed" on data) x TP ("ffn"/"heads"/"vocab"
on model) with expert parallelism on "experts" when divisible.  Per-arch
overrides are applied by the launcher (see repro.launch.strategy).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.init import ParamSpec, spec_tree
# the rule table and walk live in repro.parallel.reshard (jax-free) so the
# fleet simulator can price elastic resizes from the identical assignment;
# DEFAULT_RULES is re-exported here for compatibility
from repro.parallel.reshard import DEFAULT_RULES, assign_axes  # noqa: F401

PyTree = Any


def axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def spec_to_pspec(spec: ParamSpec, mesh: Mesh,
                  rules: Optional[Dict[str, Tuple[str, ...]]] = None) -> P:
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return P(*assign_axes(spec.shape, spec.axes, mesh_axes, rules))


def param_shardings(cfg: ModelConfig, mesh: Mesh,
                    rules: Optional[Dict[str, Tuple[str, ...]]] = None) -> PyTree:
    """NamedSharding pytree matching init_params/abstract_params layout."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, mesh, rules)),
        spec_tree(cfg),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_pspecs(cfg: ModelConfig, mesh: Mesh, rules=None) -> PyTree:
    return jax.tree.map(
        lambda s: spec_to_pspec(s, mesh, rules),
        spec_tree(cfg),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def sharded_param_bytes(cfg: ModelConfig, mesh: Mesh, rules=None) -> int:
    """Per-device parameter bytes under the rule table (for memory budgets)."""
    total = 0
    flat = jax.tree.leaves(spec_tree(cfg),
                           is_leaf=lambda x: isinstance(x, ParamSpec))
    for s in flat:
        pspec = spec_to_pspec(s, mesh, rules)
        shard_elems = math.prod(s.shape)
        for dim, part in zip(s.shape, pspec):
            if part:
                shard_elems //= axis_size(mesh, part)
        total += shard_elems * jax.dtypes.canonicalize_dtype(s.dtype).itemsize
    return total


# ---------------------------------------------------------------------------
# Input/cache shardings for the step functions
# ---------------------------------------------------------------------------

def batch_pspecs(cfg: ModelConfig, batch_tree, mesh: Mesh) -> PyTree:
    """Shard model inputs: batch dim over (pod, data)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec_for(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] == 1:   # batch-1 (long-context decode): replicate
            return P(*([None] * leaf.ndim))
        return P(dp, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec_for, batch_tree,
                        is_leaf=lambda x: hasattr(x, "shape"))


def cache_pspecs(cfg: ModelConfig, cache_tree, mesh: Mesh) -> PyTree:
    """Decode-cache sharding: batch over (pod, data), kv seq over model.

    Cache leaves (stacked): (L, b, S, hkv, hd); unstacked: (b, S, hkv, hd);
    recurrent states: (b, ...) / (L, b, ...).  Sequence-sharding the cache
    keeps per-device HBM bounded at 32k/500k depths; attention over the
    sharded seq produces partial softmax sums that GSPMD turns into a small
    logits all-gather + output reduce (see DESIGN.md §6).
    """
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = math.prod(axis_size(mesh, a) for a in dp_axes) if dp_axes else 1
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    model_ax = "model" if "model" in mesh.axis_names else None
    msize = axis_size(mesh, "model") if model_ax else 1

    def spec_for(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        if leaf.ndim == 0:
            return P()
        stacked = "blocks" in names           # scan stacks carry leading L
        kv_like = names and names[-1] in ("k", "v")
        parts = [None] * leaf.ndim
        if kv_like:
            b_dim = 1 if stacked else 0
            s_dim = b_dim + 1
            if dp and leaf.shape[b_dim] % dp_size == 0 and leaf.shape[b_dim] > 1:
                parts[b_dim] = dp
            if model_ax and leaf.shape[s_dim] % msize == 0 and msize > 1:
                parts[s_dim] = model_ax
            return P(*parts)
        # recurrent / misc states (rwkv s, conv, enc_out, last): shard batch
        b_dim = 1 if (stacked and leaf.ndim >= 2) else 0
        if dp and leaf.ndim > b_dim and leaf.shape[b_dim] % dp_size == 0 \
                and leaf.shape[b_dim] > 1:
            parts[b_dim] = dp
        return P(*parts)

    return jax.tree.map_with_path(spec_for, cache_tree)
