"""Expert-parallel MoE via shard_map all-to-all (production path).

GSPMD's handling of the sort/scatter dispatch (repro.models.moe.moe_gspmd)
can materialize token buffers across the model axis; this path makes the
communication explicit and minimal:

  tokens sharded over (pod, data) x model  ->  each device routes its local
  tokens, packs per-destination capacity buffers, all-to-alls over `model`
  (the expert-owner axis), runs its local experts, all-to-alls back, and
  combines with gate weights.  Comm volume = 2 * T_local * k * d * cf,
  exactly the GShard dispatch cost.

Used when cfg.moe_impl == "ep" and num_experts % |model| == 0 (deepseek: 64
experts over 16 = 4 local experts; mixtral's 8 experts fall back to the
GSPMD path, where expert FFNs are TP-sharded instead).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.moe import capacity, router_topk, expert_ffn, _shared


def moe_tp(x, p, cfg: ModelConfig, mesh: Mesh):
    """Tensor-parallel MoE for num_experts NOT divisible by |model|
    (e.g. Mixtral's 8 experts on a 16-wide axis): every model-rank routes
    the SAME tokens (deterministic router -> identical decisions), runs all
    experts on its d_ff shard, and a single psum over `model` combines the
    partial expert outputs — the standard Megatron-MLP comm pattern
    (one all-reduce of (T_local, d) per layer), with zero dispatch traffic.
    """
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    in_spec = P(dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None),
                None, None)

    def local(x_loc, router_w, wi, wg, wo, shared_wi, shared_wg, shared_wo):
        b, s, d = x_loc.shape
        t = b * s
        x2d = x_loc.reshape(t, d)
        gates, idx, aux = router_topk(x2d, router_w, cfg)
        cap = capacity(t, cfg)

        k = cfg.experts_per_token
        flat_e = idx.reshape(-1)
        order = jnp.argsort(flat_e)
        tok = (jnp.arange(t * k) // k)[order]
        e_sorted = flat_e[order]
        starts = jnp.searchsorted(e_sorted, jnp.arange(cfg.num_experts))
        slot = jnp.arange(t * k) - starts[e_sorted]
        keep = slot < cap
        slot_c = jnp.where(keep, slot, 0)

        buf = jnp.zeros((cfg.num_experts, cap, d), x_loc.dtype)
        rows = jnp.where(keep[:, None], x2d[tok], 0).astype(x_loc.dtype)
        buf = buf.at[e_sorted, slot_c].add(rows)

        # expert FFN with d_ff sharded over `model`: partial outputs psum'd
        ye = expert_ffn(buf, {"wi": wi, "wg": wg, "wo": wo}, cfg)

        g_sorted = gates.reshape(-1)[order]
        out_rows = ye[e_sorted, slot_c] * jnp.where(
            keep, g_sorted, 0.0)[:, None].astype(x_loc.dtype)
        out = jnp.zeros((t, d), x_loc.dtype).at[tok].add(out_rows)
        if shared_wi is not None:
            out = out + _shared(x2d, {"wi": shared_wi, "wg": shared_wg,
                                      "wo": shared_wo}, cfg)
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, mesh.axis_names)
        return out.reshape(b, s, d), aux

    expert_w = P(None, None, "model")     # wi/wg: (E, d, ff) ff-sharded
    expert_o = P(None, "model", None)     # wo: (E, ff, d)
    sh = p.get("shared")
    out, aux = jax.shard_map(
        local, mesh=mesh,
        in_specs=(in_spec, P(None, None), expert_w, expert_w, expert_o,
                  P(None, "model") if sh else None,
                  P(None, "model") if sh else None,
                  P("model", None) if sh else None),
        out_specs=(in_spec, P()),
        check_vma=False,
    )(x, p["router"],
      p["experts"]["wi"], p["experts"]["wg"], p["experts"]["wo"],
      sh["wi"] if sh else None, sh["wg"] if sh else None,
      sh["wo"] if sh else None)
    return out, aux


def moe_ep(x, p, cfg: ModelConfig, mesh: Mesh):
    """x: (b, s, d) -> (out, aux).  Requires num_experts % |model| == 0."""
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    assert cfg.num_experts % n_model == 0, (cfg.num_experts, n_model)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    e_loc = cfg.num_experts // n_model

    in_spec = P(dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None),
                "model", None)
    router_spec = jax.tree.map(lambda _: P(*([None] * 2)), p["router"])

    def local(x_loc, router_w, wi, wg, wo, shared_p):
        b, s, d = x_loc.shape
        t = b * s
        x2d = x_loc.reshape(t, d)
        gates, idx, aux = router_topk(x2d, router_w, cfg)
        cap = capacity(t, cfg)  # local capacity per expert per source device

        k = cfg.experts_per_token
        flat_e = idx.reshape(-1)
        order = jnp.argsort(flat_e)
        tok = (jnp.arange(t * k) // k)[order]
        e_sorted = flat_e[order]
        starts = jnp.searchsorted(e_sorted, jnp.arange(cfg.num_experts))
        slot = jnp.arange(t * k) - starts[e_sorted]
        keep = slot < cap
        slot_c = jnp.where(keep, slot, 0)

        # pack (E, cap, d) send buffer, grouped by destination device
        buf = jnp.zeros((cfg.num_experts, cap, d), x_loc.dtype)
        rows = jnp.where(keep[:, None], x2d[tok], 0).astype(x_loc.dtype)
        buf = buf.at[e_sorted, slot_c].add(rows)
        send = buf.reshape(n_model, e_loc, cap, d)

        # exchange over the expert-owner axis
        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                                  tiled=False)
        # recv: (n_model, e_loc, cap, d) — tokens from every source device
        xe = recv.transpose(1, 0, 2, 3).reshape(e_loc, n_model * cap, d)
        ye = expert_ffn(xe, {"wi": wi, "wg": wg, "wo": wo}, cfg)
        ye = ye.reshape(e_loc, n_model, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(ye, "model", split_axis=0, concat_axis=0,
                                  tiled=False)
        ye_full = back.reshape(cfg.num_experts, cap, d)

        g_sorted = gates.reshape(-1)[order]
        out_rows = ye_full[e_sorted, slot_c] * jnp.where(
            keep, g_sorted, 0.0)[:, None].astype(x_loc.dtype)
        out = jnp.zeros((t, d), x_loc.dtype).at[tok].add(out_rows)
        if cfg.num_shared_experts > 0:
            out = out + _shared(x2d, shared_p, cfg)
        aux = jax.lax.pmean(aux, mesh.axis_names)
        return out.reshape(b, s, d), aux

    wi, wg, wo = (p["experts"][k] for k in ("wi", "wg", "wo"))
    expert_spec = P("model", None, None)
    shared_p = p.get("shared")
    shared_spec = (jax.tree.map(lambda _: P(None, None), shared_p)
                   if shared_p is not None else None)

    out, aux = jax.shard_map(
        local, mesh=mesh,
        in_specs=(in_spec, P(None, None), expert_spec, expert_spec,
                  expert_spec, shared_spec),
        out_specs=(in_spec, P()),
        check_vma=False,
    )(x, p["router"], wi, wg, wo, shared_p)
    return out, aux
