"""Pure-jnp oracle for the grouped expert matmul."""
from __future__ import annotations

import jax.numpy as jnp


def moe_gmm_ref(x, w):
    """x: (E, C, d), w: (E, d, f) -> (E, C, f) with fp32 accumulation."""
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)
