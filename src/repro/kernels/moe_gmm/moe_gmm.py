"""Grouped (per-expert) matmul TPU kernel for MoE FFNs.

Computes out[e] = x[e] @ w[e] over the capacity-dispatched layout
x: (E, C, d), w: (E, d, f) with an MXU-aligned K-reduction pipeline:
grid (E, C_blocks, F_blocks, K_blocks), fp32 accumulator in VMEM scratch
across the sequential K dimension.

On real hardware this is megablocks-style: the capacity layout makes every
tile dense (dropped-slot rows are zero), so no ragged bookkeeping reaches
the MXU.  Tests sweep shapes/dtypes in interpret mode against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc, *, n_k: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[0]          # (bc, bk)
    w = w_ref[0]          # (bk, bf)
    acc[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[0] = acc[...].astype(o_ref.dtype)


def moe_gmm_kernel(x, w, *, block_c: int = 128, block_f: int = 128,
                   block_k: int = 512, interpret: bool = False):
    """x: (E, C, d), w: (E, d, f) -> (E, C, f)."""
    e, c, d = x.shape
    f = w.shape[2]
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    block_k = min(block_k, d)
    assert c % block_c == 0 and f % block_f == 0 and d % block_k == 0
    n_k = d // block_k
    kernel = functools.partial(_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(e, c // block_c, f // block_f, n_k),
        in_specs=[
            pl.BlockSpec((1, block_c, block_k),
                         lambda ie, ic, jf, ik: (ie, ic, ik)),
            pl.BlockSpec((1, block_k, block_f),
                         lambda ie, ic, jf, ik: (ie, ik, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda ie, ic, jf, ik: (ie, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
