"""Jitted wrapper for the grouped expert matmul kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.moe_gmm.moe_gmm import moe_gmm_kernel


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_k",
                                             "interpret"))
def moe_gmm(x, w, *, block_c=128, block_f=128, block_k=512, interpret=False):
    return moe_gmm_kernel(x, w, block_c=block_c, block_f=block_f,
                          block_k=block_k, interpret=interpret)
