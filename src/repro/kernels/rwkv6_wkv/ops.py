"""Jitted wrapper for the RWKV6 WKV kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rwkv6_wkv.rwkv6_wkv import rwkv6_wkv_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_wkv(r, k, v, logw, u, *, chunk=64, interpret=False):
    return rwkv6_wkv_kernel(r, k, v, logw, u, chunk=chunk,
                            interpret=interpret)
