"""RWKV-6 WKV recurrence TPU kernel — chunk-parallel formulation.

Per (batch, head): state S in R^{n x n};
    o_t = r_t . (S_{t-1} + u * k_t (x) v_t)
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t,  w_t = exp(logw_t)

Within a chunk of L tokens the kernel materializes the pairwise decay
tensor exp(Q_t - P_i) in VMEM ((L, L, n) fp32 — e.g. 1 MiB at L=64, n=64)
and reduces it with MXU dots; the cross-chunk state is carried in VMEM
scratch across the sequential last grid dimension.  This is the TPU
adaptation of the CUDA wkv kernel's per-thread serial loop: sequential
depth drops from seq to seq/L, the rest is dense linear algebra.

Grid: (batch, heads, n_chunks).  Blocks: r/k/v/logw tiles (1, 1, L, n).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_scr, *, L: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)       # (L, n)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)     # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)          # (1, n) bonus
    s = s_scr[...]                            # (n, n)

    p_cum = jnp.cumsum(lw, axis=0)            # P_t: through token t
    q_cum = p_cum - lw                        # Q_t: through token t-1

    # inter-chunk: o_t += (r_t * exp(Q_t)) @ S
    r_dec = r * jnp.exp(q_cum)
    o = jax.lax.dot_general(r_dec, s, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # intra-chunk: A[t, i] = sum_c r[t, c] k[i, c] exp(Q_t[c] - P_i[c]), i<t
    diff = q_cum[:, None, :] - p_cum[None, :, :]          # (L, L, n)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    tri = (i_idx < t_idx)[..., None]
    decay = jnp.where(tri, jnp.exp(diff), 0.0)
    A = jnp.einsum("tc,tic->ti", r, decay * k[None, :, :])
    o = o + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # current-token bonus: o_t += (r_t * u * k_t) . v_t
    o = o + jnp.sum(r * u * k, axis=1, keepdims=True) * v

    # state update: S <- diag(exp(P_L)) S + sum_i (k_i exp(P_L - P_i)) (x) v_i
    carry_k = k * jnp.exp(p_cum[-1][None, :] - p_cum)     # (L, n)
    s_scr[...] = jnp.exp(p_cum[-1])[:, None] * s + jax.lax.dot_general(
        carry_k, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0, 0] = o.astype(o_ref.dtype)


def rwkv6_wkv_kernel(r, k, v, logw, u, *, chunk: int = 64,
                     interpret: bool = False):
    """r, k, v, logw: (b, h, s, n); u: (h, n) -> o (b, h, s, n)."""
    b, h, s, n = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    grid = (b, h, s // chunk)
    kernel = functools.partial(_kernel, L=chunk)
    tile = pl.BlockSpec((1, 1, chunk, n), lambda ib, ih, ic: (ib, ih, ic, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile,
                  pl.BlockSpec((1, n), lambda ib, ih, ic: (ih, 0))],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((b, h, s, n), r.dtype),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
