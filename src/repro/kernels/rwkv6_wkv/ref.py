"""Per-token oracle for the RWKV6 WKV recurrence (same semantics as
repro.models.rwkv.wkv_scan, standalone for kernel validation)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def wkv_ref(r, k, v, logw, u):
    """r, k, v, logw: (b, h, s, n); u: (h, n) -> (b, h, s, n). fp64 numpy."""
    r, k, v, logw, u = (np.asarray(x, np.float64) for x in (r, k, v, logw, u))
    b, h, s, n = r.shape
    o = np.zeros_like(r)
    for ib in range(b):
        for ih in range(h):
            S = np.zeros((n, n))
            for t in range(s):
                rt, kt, vt = r[ib, ih, t], k[ib, ih, t], v[ib, ih, t]
                wt = np.exp(logw[ib, ih, t])
                o[ib, ih, t] = rt @ (S + np.outer(u[ih] * kt, vt))
                S = wt[:, None] * S + np.outer(kt, vt)
    return jnp.asarray(o, jnp.float32)
