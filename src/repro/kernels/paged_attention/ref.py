"""Pure-jnp oracle for the paged-attention decode kernel.

Gathers pages back into a dense (b, hkv, nb * block_tokens, d) view via
the block tables and runs masked single-query attention — mathematically
the kernel's online softmax, without the paging.  Also the XLA-compiled
fallback path the batched serve executor uses off-TPU (the gather jits
to a plain dynamic-gather + matmul, no Pallas interpreter in the loop).
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        window: int = 0):
    """Same contract as :func:`..paged_attention.paged_attention`."""
    b, hq, d = q.shape
    hkv, _, block_tokens, _ = k_pages.shape
    g = hq // hkv
    nb = block_tables.shape[1]
    skv = nb * block_tokens

    # (hkv, b, nb, bt, d) -> (b, hkv, skv, d): pages in table order are
    # positions in ascending order, matching the dense cache layout
    k = k_pages[:, block_tables].transpose(1, 0, 2, 3, 4) \
        .reshape(b, hkv, skv, d).astype(jnp.float32)
    v = v_pages[:, block_tables].transpose(1, 0, 2, 3, 4) \
        .reshape(b, hkv, skv, d).astype(jnp.float32)

    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * d ** -0.5
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k)

    pos = jnp.arange(skv)[None, None, None, :]
    ln = lengths[:, None, None, None]
    mask = pos < ln
    if window > 0:
        mask &= pos > (ln - 1 - window)
    s = jnp.where(mask, s, NEG_INF)

    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.where(mask, jnp.exp(s - m), 0.0)
    p = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v)
    return o.reshape(b, hq, d).astype(q.dtype)
