"""Paged-attention decode TPU kernel (pl.pallas_call + scalar-prefetch
block tables): one decode step for a batch of live slots whose KV lives
in the :class:`repro.serve.kv_cache.PagedKVCache` allocator's block
tables instead of a dense per-slot cache.

Block-table ABI (shared with ``PagedKVCache``)
----------------------------------------------
The serving KV cache is a pool of fixed-size *pages* of ``block_tokens``
token slots per kv head:

    k_pages, v_pages : (hkv, n_pages, block_tokens, head_dim)

A slot's tokens occupy the pages named by its *block table* row, in
order: absolute position ``p`` of slot ``b`` lives in page
``block_tables[b, p // block_tokens]`` at in-page offset
``p % block_tokens``.  ``lengths[b]`` is the number of valid positions
(attention span) for slot ``b``; rows past their table's populated
prefix may point anywhere (conventionally a null page) — they are never
read because the length mask excludes them.  ``lengths[b] == 0`` marks
an *inactive* batch row: the kernel skips every page and writes zeros,
which is what lets a fixed-width batched executor mask empty rows
instead of recompiling at a new width.

``block_tokens`` is read off the page pool's shape and **is** the
kernel's kv tile: each grid step DMAs exactly one
``(block_tokens, head_dim)`` page into VMEM, so allocator blocks map
1:1 onto kernel ``block_k`` grid iterations with no partial-tile waste.
The allocator's default (``FLASH_ATTENTION_BLOCK_K`` = 128, the Pallas
flash-attention kv tile) keeps both kernels fed whole MXU-aligned
tiles; a pin test holds the two constants equal.

TPU adaptation notes: the page gather is a *data-dependent* BlockSpec —
``pltpu.PrefetchScalarGridSpec`` prefetches the block table and length
vectors into SMEM so the k/v index maps can address
``k_pages[ih, block_tables[ib, ik]]`` per grid step; the kv-page loop is
the innermost grid dimension (TPU grids iterate sequentially, so the
online-softmax running max/denominator live in VMEM scratch across
pages); pages wholly past ``lengths[ib]`` skip their FLOPs with
``pl.when`` but still run their grid step, keeping the grid static.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30

# The kernel's kv tile == the serve allocator's default page size ==
# the flash-attention kernel's block_k (pinned against each other and
# against repro.serve.kv_cache.FLASH_ATTENTION_BLOCK_K by test).
DEFAULT_BLOCK_TOKENS = 128


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *,
            scale: float, block_tokens: int, window: int):
    ib = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[ib]

    # Pages at or past the valid span contribute nothing — skip their
    # FLOPs entirely.  (This also keeps zero-length rows from ever
    # touching the scratch, so inactive rows finish with l == 0 and the
    # epilogue emits exact zeros instead of a softmax over masked junk.)
    @pl.when(ik * block_tokens < length)
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (g, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bt, d)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bt, d)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (g, bt)

        kv_pos = ik * block_tokens + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = kv_pos < length
        if window > 0:
            # the (single) query sits at absolute position length - 1
            mask &= kv_pos > (length - 1 - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                   # (g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                                # (g, bt)
        alpha = jnp.exp(m_prev - m_new)                       # (g, 1)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    window: int = 0, interpret: bool = False):
    """One-token paged attention for a batch of slots.

    q: (b, hq, d) — one query token per slot; k_pages, v_pages:
    (hkv, n_pages, block_tokens, d); block_tables: (b, nb) int32;
    lengths: (b,) int32 valid positions per slot (0 = inactive row,
    output zeros).  hq % hkv == 0 (GQA).  Returns (b, hq, d) in
    q.dtype; softmax/accumulation in fp32.
    """
    b, hq, d = q.shape
    hkv, n_pages, block_tokens, _ = k_pages.shape
    assert hq % hkv == 0
    g = hq // hkv
    nb = block_tables.shape[1]
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_kernel, scale=d ** -0.5,
                               block_tokens=block_tokens, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda ib, ih, ik, bt, ln: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, block_tokens, d),
                         lambda ib, ih, ik, bt, ln: (ih, bt[ib, ik], 0, 0)),
            pl.BlockSpec((1, 1, block_tokens, d),
                         lambda ib, ih, ik, bt, ln: (ih, bt[ib, ik], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ib, ih, ik, bt, ln: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(b, hq, d)
