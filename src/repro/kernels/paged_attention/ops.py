"""Jitted public wrappers for the paged-attention decode kernel.

``paged_attention_decode`` dispatches between the Pallas kernel and the
pure-jnp gather reference by a static ``impl`` flag:

* ``"kernel"`` — the Pallas kernel (``interpret=True`` off-TPU so CPU
  CI exercises the real code path);
* ``"ref"`` — the XLA-compiled gather oracle (fast on CPU, where the
  Pallas interpreter would dominate wall-clock);
* ``"auto"`` — kernel on TPU backends, ref elsewhere.

Both impls share one contract (see the kernel docstring): the batched
executor and benchmarks call this wrapper only.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.paged_attention import (
    DEFAULT_BLOCK_TOKENS, paged_attention)
from repro.kernels.paged_attention.ref import paged_attention_ref

__all__ = ["DEFAULT_BLOCK_TOKENS", "paged_attention_decode",
           "resolve_impl"]


def resolve_impl(impl: str = "auto") -> str:
    """Resolve "auto" to "kernel" (TPU) or "ref" (anything else)."""
    if impl != "auto":
        return impl
    return "kernel" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("window", "impl", "interpret"))
def paged_attention_decode(q, k_pages, v_pages, block_tables, lengths, *,
                           window: int = 0, impl: str = "auto",
                           interpret: bool = False):
    """One decode step of paged attention; see the kernel docstring.

    q: (b, hq, d); k_pages/v_pages: (hkv, n_pages, block_tokens, d);
    block_tables: (b, nb) int32; lengths: (b,) int32.  Returns
    (b, hq, d).
    """
    impl = resolve_impl(impl)
    if impl == "kernel":
        return paged_attention(q, k_pages, v_pages, block_tables, lengths,
                               window=window, interpret=interpret)
    if impl == "ref":
        return paged_attention_ref(q, k_pages, v_pages, block_tables,
                                   lengths, window=window)
    raise ValueError(f"unknown paged-attention impl: {impl!r}")
