"""Pure-jnp oracle: associative scan of h_t = a_t h_{t-1} + b_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b):
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    _, h = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return h.astype(a.dtype)
