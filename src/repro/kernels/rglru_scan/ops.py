"""Jitted wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rglru_scan.rglru_scan import rglru_scan_kernel


@functools.partial(jax.jit, static_argnames=("block_s", "block_c",
                                             "interpret"))
def rglru_scan(a, b, *, block_s=256, block_c=128, interpret=False):
    return rglru_scan_kernel(a, b, block_s=block_s, block_c=block_c,
                             interpret=interpret)
