"""RG-LRU linear-recurrence TPU kernel: h_t = a_t * h_{t-1} + b_t.

The gate matmuls stay in XLA (MXU-friendly as plain dots); the kernel owns
the sequential recurrence, which on TPU is VPU-bound: we tile channels into
VMEM-resident lanes and run the time loop in-register, carrying h in VMEM
scratch across sequence-block grid steps (grid's last dim iterates
sequentially on TPU).

Block layout: a, b tiles (1, block_s, block_c); grid (batch, n_chan_blocks,
n_seq_blocks) — channels are 128-lane aligned on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_scr, *, block_s: int):
    isq = pl.program_id(2)

    @pl.when(isq == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)     # (block_s, block_c)
    bb = b_ref[0].astype(jnp.float32)
    h0 = h_scr[...]                      # (1, block_c)

    def body(t, carry):
        h, out = carry
        h = a[t][None] * h + bb[t][None]
        out = jax.lax.dynamic_update_slice_in_dim(out, h, t, axis=0)
        return h, out

    h, out = jax.lax.fori_loop(
        0, block_s, body, (h0, jnp.zeros((block_s, a.shape[1]), jnp.float32)))
    o_ref[0] = out.astype(o_ref.dtype)
    h_scr[...] = h


def rglru_scan_kernel(a, b, *, block_s: int = 256, block_c: int = 128,
                      interpret: bool = False):
    """a, b: (batch, seq, channels) -> scanned h (batch, seq, channels)."""
    bs, seq, ch = a.shape
    block_s = min(block_s, seq)
    block_c = min(block_c, ch)
    grid = (bs, pl.cdiv(ch, block_c), pl.cdiv(seq, block_s))
    kernel = functools.partial(_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_c),
                         lambda ib, ic, isq: (ib, isq, ic)),
            pl.BlockSpec((1, block_s, block_c),
                         lambda ib, ic, isq: (ib, isq, ic)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_c),
                               lambda ib, ic, isq: (ib, isq, ic)),
        out_shape=jax.ShapeDtypeStruct((bs, seq, ch), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_c), jnp.float32)],
        interpret=interpret,
    )(a, b)
