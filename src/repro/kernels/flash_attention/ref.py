"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (b, hq, sq, d); k, v: (b, hkv, skv, d) -> (b, hq, sq, d)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32) * d ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(b, hq, sq, d).astype(q.dtype)
