"""Jitted public wrapper for the flash attention kernel (bshd layout)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_bshd(q, k, v, *, causal=True, window=0, block_q=128,
                         block_k=128, interpret=False):
    """q: (b, sq, hq, d); k, v: (b, skv, hkv, d) — model-layout wrapper."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention(qt, kt, vt, causal=causal, window=window,
                        block_q=block_q, block_k=block_k, interpret=interpret)
    return o.transpose(0, 2, 1, 3)
