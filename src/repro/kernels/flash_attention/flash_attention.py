"""Flash attention TPU kernel (pl.pallas_call + explicit BlockSpec VMEM
tiling): online-softmax over kv blocks, GQA head mapping, causal +
sliding-window masking.

TPU adaptation notes (DESIGN.md §3): the CUDA flash kernel's warp-level
softmax reductions become VPU vector ops over an (bq, bk) VMEM tile; the
kv loop is the innermost grid dimension (TPU grids iterate sequentially, so
the running max/denominator live in VMEM scratch across kv steps); block
shapes are MXU-aligned (bq, bk multiples of 128 on real hardware — tests
sweep smaller interpret-mode tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, seq_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_kv
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                       # (bq, 1)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (b, hq, sq, d); k, v: (b, hkv, skv, d).  hq % hkv == 0 (GQA).

    Returns (b, hq, sq, d) in q.dtype.  Softmax/accumulation in fp32.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    scale = d ** -0.5

    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(skv, block_k)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_kv=skv)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
