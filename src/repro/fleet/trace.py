"""Versioned JSONL event traces: record a run once, replay it exactly.

A trace is the cross-layer ``(Interval, pg)`` event stream observed on a
:class:`~repro.core.ledger.GoodputLedger`, serialized one JSON object per
line.  Every emitter (``FleetSim`` — ``emitter: fleet``, ``Orchestrator``
— ``emitter: runtime``, the serve loop — ``emitter: serve``) tags its
segment dict with its provenance plus the responsible stack layer
(``layer:`` a ``repro.core.goodput.Layer`` value), so one recorder
attached to a shared ledger captures the whole stack and replay
reconstructs per-layer sub-ledgers — and the attribution waterfall
(``repro.core.attribution``) — for free.

Schema (version 1) — three line kinds, in file order:

  {"kind": "header", "version": 1, "capacity_chip_time": .., "window": ..,
   "meta": {..}}
  {"kind": "event", "job": .., "phase": "step", "t0": .., "t1": ..,
   "chips": .., "pg": .., "seg": {..}}            # one per ledger event
  {"kind": "footer", "totals": {..}}              # ledger.totals() snapshot

Versioning rules: ``TRACE_VERSION`` bumps whenever a field is renamed,
removed, or its semantics change; adding an optional field is *not* a bump
(readers ignore unknown keys).  ``loads`` refuses versions it does not
know.  Golden traces under ``tests/golden/`` are regenerated — never
hand-edited — via ``python -m repro.fleet.trace --refresh-golden``.

Determinism contract: floats serialize through Python's shortest-roundtrip
repr (exact), events are written in emission order, and every random
stream in the simulator is seeded per component — so the same (scenario,
seed) produces a byte-identical trace, and ``replay(record(sim))``
reproduces the original ledger totals bit-for-bit.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
from typing import Dict, List, Optional

from repro.core.goodput import Interval, Phase
from repro.core.ledger import GoodputLedger

TRACE_VERSION = 2
GOLDEN_DIR = pathlib.Path(__file__).resolve().parents[3] / "tests" / "golden"

_JSON = dict(sort_keys=True, separators=(",", ":"))


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One recorded ledger event (an Interval plus its pg weight)."""
    job_id: str
    phase: str
    t0: float
    t1: float
    chips: int
    pg: float
    segment: Dict[str, str]

    def to_interval(self) -> Interval:
        return Interval(job_id=self.job_id, phase=Phase(self.phase),
                        t0=self.t0, t1=self.t1, chips=self.chips,
                        segment=dict(self.segment))


@dataclasses.dataclass
class Trace:
    """A parsed trace: header metadata, the event stream, and the exact
    ledger totals observed at record time (the replay target)."""
    capacity_chip_time: float
    window: float
    meta: Dict[str, object]
    events: List[TraceEvent]
    totals: Dict[str, object]
    version: int = TRACE_VERSION

    # ---- serialization ---------------------------------------------------
    def dumps(self) -> str:
        lines = [json.dumps({"kind": "header", "version": self.version,
                             "capacity_chip_time": self.capacity_chip_time,
                             "window": self.window, "meta": self.meta},
                            **_JSON)]
        for ev in self.events:
            lines.append(json.dumps(
                {"kind": "event", "job": ev.job_id, "phase": ev.phase,
                 "t0": ev.t0, "t1": ev.t1, "chips": ev.chips, "pg": ev.pg,
                 "seg": ev.segment}, **_JSON))
        lines.append(json.dumps({"kind": "footer", "totals": self.totals},
                                **_JSON))
        return "\n".join(lines) + "\n"

    @classmethod
    def loads(cls, text: str) -> "Trace":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty trace")
        header = json.loads(lines[0])
        if header.get("kind") != "header":
            raise ValueError("trace must start with a header line")
        if header.get("version") != TRACE_VERSION:
            raise ValueError(
                f"unsupported trace version {header.get('version')!r} "
                f"(this reader supports {TRACE_VERSION})")
        events: List[TraceEvent] = []
        totals: Dict[str, object] = {}
        for ln in lines[1:]:
            obj = json.loads(ln)
            kind = obj.get("kind")
            if kind == "event":
                events.append(TraceEvent(
                    job_id=obj["job"], phase=obj["phase"], t0=obj["t0"],
                    t1=obj["t1"], chips=obj["chips"], pg=obj["pg"],
                    segment=obj.get("seg", {})))
            elif kind == "footer":
                totals = obj["totals"]
            else:
                raise ValueError(f"unknown trace line kind {kind!r}")
        return cls(capacity_chip_time=header["capacity_chip_time"],
                   window=header["window"], meta=header.get("meta", {}),
                   events=events, totals=totals,
                   version=header["version"])

    def dump(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.dumps())
        return path

    @classmethod
    def load(cls, path) -> "Trace":
        return cls.loads(pathlib.Path(path).read_text())


class TraceRecorder:
    """Subscribes to a ledger's pg-aware event hook and accumulates the
    stream; ``finalize`` snapshots the ledger totals into a Trace."""

    def __init__(self, meta: Optional[Dict[str, object]] = None):
        self.meta = dict(meta or {})
        self._events: List[TraceEvent] = []

    def attach(self, ledger: GoodputLedger) -> "TraceRecorder":
        ledger.subscribe_events(self._on_event, batch_fn=self._on_batch)
        return self

    def _on_event(self, iv: Interval, pg: float) -> None:
        self._events.append(TraceEvent(
            job_id=iv.job_id, phase=iv.phase.value, t0=iv.t0, t1=iv.t1,
            chips=iv.chips, pg=pg, segment=dict(iv.segment)))

    def _on_batch(self, batch) -> None:
        # columnar twin of _on_event: same TraceEvents in the same order
        # (segment dicts are copied — the sim interns and reuses them)
        self._events.extend(TraceEvent(
            job_id=j, phase=ph.value, t0=a, t1=b, chips=c, pg=pg,
            segment=dict(seg))
            for j, ph, a, b, c, pg, seg in zip(
                batch.job_ids, batch.phases, batch.t0, batch.t1,
                batch.chips, batch.pgs, batch.segments))

    def finalize(self, ledger: GoodputLedger) -> Trace:
        return Trace(capacity_chip_time=ledger.capacity_chip_time,
                     window=ledger.window, meta=self.meta,
                     events=self._events, totals=ledger.totals())


def record(sim, meta: Optional[Dict[str, object]] = None) -> Trace:
    """Run ``sim`` under a recorder and return the trace.

    The recorder must observe the stream from the first event, so the
    sim's ledger has to be empty — attach-then-run.  For cross-layer
    traces (orchestrator / serve emitting into the same ledger), attach a
    :class:`TraceRecorder` to the shared ledger directly.
    """
    if sim.ledger.n_events:
        raise ValueError(
            "record(sim) must attach before any event is emitted; the "
            "sim's ledger already holds events — build a fresh sim (or "
            "attach a TraceRecorder to the shared ledger up front)")
    cfg = sim.cfg
    info: Dict[str, object] = {
        "seed": cfg.seed, "n_pods": cfg.n_pods, "pod_size": cfg.pod_size,
        "horizon": cfg.horizon,
        "scenario": cfg.scenario.name if cfg.scenario else None,
        "placement": sim.placement.name, "preemption": sim.preemption.name,
        "defrag": sim.defrag.name,
        "slice_repair_s": cfg.slice_repair_s,
    }
    # workload provenance (set by scenarios.build_sim): with it, a trace
    # alone rebuilds the exact sim — the advisor's counterfactual entry
    # point (repro.fleet.advisor.from_trace)
    workload = getattr(sim, "workload_info", None)
    if workload is not None:
        info["workload"] = workload
    info.update(meta or {})
    rec = TraceRecorder(meta=info).attach(sim.ledger)
    sim.run()
    return rec.finalize(sim.ledger)


def replay(trace: Trace, ledger: Optional[GoodputLedger] = None
           ) -> GoodputLedger:
    """Feed a trace's events through a ledger in recorded order.

    With a fresh default ledger this reproduces the recorded totals
    bit-for-bit (identical float operations in identical order); pass an
    existing ledger to merge several traces into one fleet-wide view.
    """
    if ledger is None:
        ledger = GoodputLedger(capacity_chip_time=trace.capacity_chip_time,
                               window=trace.window, retain_intervals=False)
    for ev in trace.events:
        ledger.record(ev.to_interval(), pg=ev.pg)
    return ledger


def verify(trace: Trace) -> Dict[str, object]:
    """Replay a trace and check the footer totals reproduce exactly.

    Returns the replayed totals; raises ``ValueError`` on any drift —
    the golden-trace regression condition.
    """
    got = replay(trace).totals()
    if got != trace.totals:
        raise ValueError(
            "replay drift: totals do not reproduce the recorded footer\n"
            f"  recorded: {trace.totals}\n  replayed: {got}")
    return got


# ---------------------------------------------------------------------------
# CLI: golden-trace maintenance
# ---------------------------------------------------------------------------

def refresh_golden(golden_dir=GOLDEN_DIR) -> List[pathlib.Path]:
    """Re-record every scenario preset's golden trace (intentional
    regeneration after a simulator behaviour change)."""
    from repro.fleet.scenarios import SCENARIOS, golden_sim

    golden_dir = pathlib.Path(golden_dir)
    written = []
    for name in sorted(SCENARIOS):
        trace = record(golden_sim(name))
        written.append(trace.dump(golden_dir / f"{name}.jsonl"))
    return written


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="record / verify / refresh goodput event traces")
    ap.add_argument("--refresh-golden", action="store_true",
                    help="re-record tests/golden/<preset>.jsonl for every "
                         "scenario preset")
    ap.add_argument("--golden-dir", default=str(GOLDEN_DIR))
    ap.add_argument("--verify", nargs="+", metavar="TRACE",
                    help="replay trace file(s) and check footer totals "
                         "reproduce exactly")
    ap.add_argument("--record", metavar="PRESET",
                    help="record one scenario preset (golden-sized sim)")
    ap.add_argument("-o", "--out", default=None,
                    help="output path for --record")
    args = ap.parse_args(argv)

    if args.refresh_golden:
        for p in refresh_golden(args.golden_dir):
            print(f"wrote {p}")
        return
    if args.verify:
        for path in args.verify:
            verify(Trace.load(path))
            print(f"ok {path}")
        return
    if args.record:
        from repro.fleet.scenarios import golden_sim

        trace = record(golden_sim(args.record))
        out = args.out or f"{args.record}.jsonl"
        print(f"wrote {trace.dump(out)} ({len(trace.events)} events)")
        return
    ap.error("choose one of --refresh-golden / --verify / --record")


if __name__ == "__main__":
    main()
