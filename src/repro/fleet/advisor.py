"""Counterfactual what-if optimization advisor (paper §6–§7, Figs 14–15).

The attribution waterfall (``repro.core.attribution``) answers *where the
goodput went*; this module answers *which fix buys the most back*.  It
takes a baseline — a scenario preset, a :class:`Scenario`, or a recorded
trace — and replays the simulator under a catalog of counterfactual
knobs, each a single optimization the paper evaluates:

  * ``async_checkpointing``     — snapshot-to-host instead of sync writes;
  * ``checkpoint_interval_daly``— re-tune the checkpoint interval to the
    Daly/Young optimum ``sqrt(2 * write_cost * slice_MTBF)``;
  * ``compile_cache_warm``      — every launch hits the AOT cache;
  * ``data_pipeline_2x``        — halve input-pipeline stall fractions;
  * ``single_controller``       — migrate multi-client jobs to the
    Pathways-style single-controller framework;
  * ``scheduler_paper_policies``— swap placement/preemption/defrag to the
    paper's policy combination;
  * ``generation_upgrade``      — upgrade every pod to the best hardware
    generation present;
  * ``elastic_resize``          — let every job restart degraded (shed
    slices / halve width) instead of queueing for its full shape;
  * ``multi_slice_gang``        — run every even-width training job as a
    2-slice gang so a failure kills one slice, not the job.

Because the workload generation is hermetic (``scenarios.build_sim``),
every counterfactual run sees the byte-identical job population with only
the knob applied — the MAD-Max/TpuGraphs-style controlled replay that
makes "recovered MPG" a defensible ranking rather than seed noise.
Sweeps inherit ``build_sim``'s default vectorized event core, and the
byte-identity equivalence gate (``tests/test_golden_traces.py``) is what
licenses that: a what-if delta computed on the fast engine is the same
delta the reference engine would report, bit for bit.

Demand saturation: with a *finite* fixed workload, an optimization mostly
finishes the same work sooner and the saved chip-time shows up as
unallocated capacity, not extra goodput — every knob's recovered MPG
collapses toward zero.  A production fleet has a backlog (the paper's
quarter-scale fleet is demand-rich), so by default ``what_if`` oversizes
the workload to ``SATURATED_LOAD`` of capacity: freed capacity is always
re-consumed and recovered MPG measures real extra throughput.  Trace
baselines are never resized (the rebuilt sim must reproduce the recorded
footer bit-for-bit before any delta is trusted); pass ``saturate=None``
to opt a preset out.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Union

from repro.core.attribution import AttributionWaterfall
from repro.core.goodput import GoodputReport
from repro.core.hardware import GENERATIONS
from repro.fleet.job import JobSpec
from repro.fleet.scenarios import SCENARIOS, Scenario, build_sim
from repro.fleet.sim import SimConfig
from repro.fleet.trace import Trace

# the fleet-wide per-chip MTBF and async-snapshot device pause the
# simulator assumes (scenario shocks scale the MTBF via
# Scenario.mtbf_factor) — read from SimConfig so a retune there cannot
# silently desynchronize the Daly-optimum knob
_SIM_DEFAULTS = SimConfig()
CHIP_MTBF = _SIM_DEFAULTS.chip_mtbf
ASYNC_SNAPSHOT_PAUSE = _SIM_DEFAULTS.async_snapshot_pause

# default demand oversizing for preset/scenario baselines (see module
# docstring): work sized to 1.5x capacity keeps every counterfactual run
# backlogged, so recovered capacity converts into measured goodput
SATURATED_LOAD = 1.5


@dataclasses.dataclass(frozen=True)
class Case:
    """One runnable counterfactual: a scenario plus build_sim kwargs and
    an optional per-job rewrite applied to the generated workload."""
    scenario: Scenario
    kwargs: Dict[str, object]
    job_mutator: Optional[Callable[[JobSpec], JobSpec]] = None

    def with_jobs(self, fn: Callable[[JobSpec], JobSpec]) -> "Case":
        prev = self.job_mutator
        chained = fn if prev is None else (lambda j: fn(prev(j)))
        return dataclasses.replace(self, job_mutator=chained)

    def with_kwargs(self, **kw) -> "Case":
        return dataclasses.replace(self, kwargs={**self.kwargs, **kw})

    def with_scenario(self, scenario: Scenario) -> "Case":
        return dataclasses.replace(self, scenario=scenario)


@dataclasses.dataclass(frozen=True)
class Knob:
    """One counterfactual optimization: name, the MPG term it targets
    (for reporting), and a Case -> Case transform.

    ``addresses`` names the waterfall loss buckets the knob can recover
    from; when the baseline shows zero chip-time in every listed bucket,
    ``what_if`` skips the resimulation outright (recovered is 0.0 by
    construction — there is nothing to recover).  ``skip_when`` is a
    structural predicate on the Case for knobs whose no-op condition is
    not a loss bucket (e.g. the policies are already the paper combo).
    An empty ``addresses`` with no predicate means always resimulate."""
    name: str
    description: str
    targets: str                      # "SG" | "RG" | "PG" (primary term)
    build: Callable[[Case], Case]
    addresses: tuple = ()             # loss-bucket names (see LOSS_BUCKETS)
    skip_when: Optional[Callable[[Case], bool]] = None


def _daly_interval(spec: JobSpec, mtbf_factor: float) -> float:
    """Daly/Young first-order optimal checkpoint interval for the job's
    slice: sqrt(2 * write_cost * slice_MTBF), clamped to [60s, 1d]."""
    slice_mtbf = CHIP_MTBF * mtbf_factor / max(1, spec.chips)
    write = (ASYNC_SNAPSHOT_PAUSE if spec.async_checkpoint
             else spec.checkpoint_write)
    return min(86400.0, max(60.0, math.sqrt(2.0 * write * slice_mtbf)))


def _best_generation(gens) -> str:
    return max(gens, key=lambda g: GENERATIONS[g].peak_flops_bf16)


def _knob_async(case: Case) -> Case:
    return case.with_jobs(
        lambda j: dataclasses.replace(j, async_checkpoint=True))


def _knob_daly(case: Case) -> Case:
    factor = case.scenario.mtbf_factor
    return case.with_jobs(lambda j: dataclasses.replace(
        j, checkpoint_interval=_daly_interval(j, factor)))


def _knob_cache(case: Case) -> Case:
    return case.with_jobs(
        lambda j: dataclasses.replace(j, compile_cache_hit=True))


def _knob_data(case: Case) -> Case:
    return case.with_jobs(lambda j: dataclasses.replace(
        j, data_stall_frac=j.data_stall_frac * 0.5))


def _knob_pathways(case: Case) -> Case:
    return case.with_jobs(
        lambda j: dataclasses.replace(j, framework="jax-pathways"))


def _knob_policies(case: Case) -> Case:
    return case.with_kwargs(placement="best_fit", preemption="protect_xl",
                            defrag="drain_for_xl")


def _knob_elastic(case: Case) -> Case:
    return case.with_jobs(lambda j: dataclasses.replace(j, elastic=True))


def _knob_gang(case: Case) -> Case:
    # widen coverage beyond the workload's default gang band: any still-
    # single-slice training job of even width splits into a 2-slice gang
    return case.with_jobs(lambda j: dataclasses.replace(j, n_slices=2)
                          if j.phase_kind == "train" and j.n_slices == 1
                          and j.chips >= 2 and j.chips % 2 == 0 else j)


def _knob_generation(case: Case) -> Case:
    gens = case.scenario.pod_generations
    if not gens:
        return case                   # already homogeneous: a no-op
    best = _best_generation(gens)
    return case.with_scenario(dataclasses.replace(
        case.scenario, name=f"{case.scenario.name}+upgrade",
        pod_generations=(best,)))


_PAPER_POLICIES = {"placement": "best_fit", "preemption": "protect_xl",
                   "defrag": "drain_for_xl"}


def _already_paper_policies(case: Case) -> bool:
    # build_sim's defaults ARE the paper combo, so an absent kwarg means
    # the knob would rebuild the byte-identical sim
    return all(case.kwargs.get(k, v) == v for k, v in
               _PAPER_POLICIES.items())


def _homogeneous_fleet(case: Case) -> bool:
    return len(set(case.scenario.pod_generations)) <= 1


KNOBS: Dict[str, Knob] = {k.name: k for k in (
    Knob("async_checkpointing",
         "async snapshot-to-host checkpoints for every job", "RG",
         _knob_async, addresses=("checkpoint_write",)),
    Knob("checkpoint_interval_daly",
         "re-tune checkpoint intervals to sqrt(2*write*MTBF)", "RG",
         _knob_daly, addresses=("checkpoint_write", "failure_rollback",
                                "preemption_rollback")),
    Knob("compile_cache_warm",
         "every launch hits the AOT compile cache", "RG", _knob_cache,
         addresses=("compile",)),
    Knob("data_pipeline_2x",
         "halve input-pipeline stall fractions", "RG", _knob_data,
         addresses=("input_stall",)),
    Knob("single_controller",
         "migrate multi-client jobs to the single-controller framework",
         "RG", _knob_pathways),
    Knob("scheduler_paper_policies",
         "swap to best-fit placement + protect-XL preemption + "
         "drain-for-XL defrag", "SG", _knob_policies,
         skip_when=_already_paper_policies),
    Knob("generation_upgrade",
         "upgrade every pod to the best hardware generation present",
         "PG", _knob_generation, skip_when=_homogeneous_fleet),
    Knob("elastic_resize",
         "restart preempted/failed jobs degraded instead of queueing "
         "for the full shape", "SG", _knob_elastic),
    Knob("multi_slice_gang",
         "run every even-width training job as a 2-slice gang "
         "(failures kill a slice, not the job)", "RG", _knob_gang),
)}


# ---------------------------------------------------------------------------
# baseline construction
# ---------------------------------------------------------------------------

def baseline_case(source: Union[str, Scenario, Trace], **kwargs) -> Case:
    """A Case from a preset name, a Scenario, or a recorded Trace."""
    if isinstance(source, Trace):
        if kwargs:
            # silently ignoring overrides would return a plausible report
            # for a configuration the caller never asked for
            raise ValueError(
                "a Trace baseline is fully determined by its recorded "
                f"header; overrides {sorted(kwargs)} cannot apply — "
                "call what_if on the preset/Scenario instead")
        return from_trace(source)
    if isinstance(source, str):
        if source not in SCENARIOS:
            raise ValueError(f"unknown scenario preset {source!r}; "
                             f"choose from {sorted(SCENARIOS)}")
        source = SCENARIOS[source]
    kwargs = dict(kwargs)
    # job_mutator is a Case field, not a build_sim kwarg, so knob mutators
    # chain onto it instead of silently replacing it
    job_mutator = kwargs.pop("job_mutator", None)
    return Case(scenario=source, kwargs=kwargs, job_mutator=job_mutator)


def from_trace(trace: Trace) -> Case:
    """Rebuild the exact sim behind a recorded trace from its header.

    Needs the workload-provenance meta that ``scenarios.build_sim``
    stamps (``workload: {n_jobs, size_mix}``) plus the scenario/policy/
    shape fields ``trace.record`` always writes.  ``what_if`` then
    verifies the rebuilt baseline reproduces the trace footer bit-for-bit
    before trusting any counterfactual delta.
    """
    meta = trace.meta
    workload = meta.get("workload")
    if not workload:
        raise ValueError(
            "trace has no workload-provenance meta (recorded before the "
            "advisor existed, or from a hand-built sim); re-record via "
            "scenarios.build_sim, or call what_if on the preset directly")
    scenario = meta.get("scenario")
    if scenario not in SCENARIOS:
        raise ValueError(f"trace scenario {scenario!r} is not a known "
                         f"preset; choose from {sorted(SCENARIOS)}")
    size_mix = workload.get("size_mix")
    pg_table = workload.get("pg_table")
    return Case(scenario=SCENARIOS[scenario], kwargs=dict(
        n_jobs=workload["n_jobs"], seed=meta["seed"],
        n_pods=meta["n_pods"], pod_size=meta["pod_size"],
        horizon=meta["horizon"], placement=meta["placement"],
        preemption=meta["preemption"], defrag=meta["defrag"],
        # older traces predate the repair-window knob; default 0 matches
        # the behaviour they were recorded under
        slice_repair_s=meta.get("slice_repair_s", 0.0),
        # pair lists preserve the insertion order the workload's size
        # picker depends on (trace JSON sorts plain dict keys)
        size_mix=dict(size_mix) if size_mix else None,
        pg_table=dict(pg_table) if pg_table else {}))


# ---------------------------------------------------------------------------
# the what-if engine
# ---------------------------------------------------------------------------

def run_case(case: Case):
    """Simulate one case on a fresh streaming ledger with an attribution
    waterfall attached; returns (sim, report, waterfall)."""
    sim = build_sim(case.scenario, job_mutator=case.job_mutator,
                    retain_intervals=False,
                    **{k: v for k, v in case.kwargs.items()
                       if k != "retain_intervals"})
    wf = AttributionWaterfall().attach(sim.ledger)
    sim.run()
    wf.assert_conserves(sim.ledger)   # every advisor run is self-checking
    return sim, sim.report(), wf


def _composition(rep: GoodputReport) -> Dict[str, float]:
    return {"SG": rep.sg, "RG": rep.rg, "PG": rep.pg, "MPG": rep.mpg}


def _should_skip(knob: Knob, case: Case,
                 base_buckets: Dict[str, float]) -> bool:
    """True when the baseline proves the knob can recover nothing: its
    structural no-op predicate holds, or every loss bucket it addresses
    holds zero chip-time."""
    if knob.skip_when is not None and knob.skip_when(case):
        return True
    if knob.addresses:
        return all(base_buckets.get(b, 0.0) == 0.0 for b in knob.addresses)
    return False


def what_if(source: Union[str, Scenario, Trace],
            knobs: Optional[List[str]] = None,
            saturate: Union[str, float, None] = "auto",
            skip_unaddressable: bool = True,
            **kwargs) -> Dict[str, object]:
    """Rank counterfactual knobs by recovered MPG on one baseline.

    Returns a JSON-ready report: the baseline MPG composition and
    attribution waterfall, plus one row per knob — its counterfactual
    composition, the recovered MPG (and per-term deltas), and the
    recovered ideal chip-time ``d_MPG * capacity`` — sorted largest
    recovery first.

    ``saturate``: target demand load for the workload ("auto" =
    ``SATURATED_LOAD`` for presets/scenarios, untouched for traces —
    see the module docstring; ``None`` = keep the scenario's own load).

    ``skip_unaddressable``: early-exit knobs whose addressable loss is
    provably zero in the baseline waterfall (or whose structural no-op
    predicate holds) instead of resimulating them — their rows report the
    baseline composition, ``recovered_mpg: 0.0``, and ``skipped: true``.
    The ranking is unchanged: a skipped knob's resimulation would rebuild
    the byte-identical sim (see ``tests/test_advisor.py``).
    """
    case = baseline_case(source, **kwargs)
    if saturate == "auto":
        saturate = None if isinstance(source, Trace) else SATURATED_LOAD
    if saturate is not None:
        case = case.with_scenario(dataclasses.replace(
            case.scenario, target_load=float(saturate)))
    base_sim, base_rep, base_wf = run_case(case)
    baseline: Dict[str, object] = {
        **_composition(base_rep),
        "capacity_chip_time": base_rep.capacity_chip_time,
        "target_load": case.scenario.target_load,
        "waterfall": base_wf.report(),
    }
    if isinstance(source, Trace):
        # controlled-replay guard: the rebuilt baseline must reproduce
        # the recorded footer exactly, or the deltas mean nothing
        rebuilt = base_sim.ledger.totals()
        if rebuilt != source.totals:
            raise ValueError(
                "rebuilt baseline does not reproduce the trace footer — "
                "the trace was recorded under different simulator "
                f"behaviour\n  recorded: {source.totals}\n"
                f"  rebuilt:  {rebuilt}")
        baseline["reproduces_trace"] = True

    names = list(KNOBS) if knobs is None else list(knobs)
    base_buckets = base_wf.bucket_totals()
    rows = []
    for name in names:
        knob = KNOBS[name]
        skipped = skip_unaddressable and _should_skip(knob, case,
                                                      base_buckets)
        rep = base_rep if skipped else run_case(knob.build(case))[1]
        rows.append({
            "knob": name,
            "description": knob.description,
            "targets": knob.targets,
            **_composition(rep),
            "recovered_mpg": rep.mpg - base_rep.mpg,
            "d_sg": rep.sg - base_rep.sg,
            "d_rg": rep.rg - base_rep.rg,
            "d_pg": rep.pg - base_rep.pg,
            "recovered_ideal_chip_time":
                (rep.mpg - base_rep.mpg) * base_rep.capacity_chip_time,
            "skipped": skipped,
        })
    rows.sort(key=lambda r: (-r["recovered_mpg"], r["knob"]))
    return {"scenario": case.scenario.name,
            "baseline": baseline,
            "ranking": rows}


def knob_names() -> List[str]:
    return sorted(KNOBS)
