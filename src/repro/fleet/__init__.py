from repro.fleet.cluster import Cluster  # noqa: F401
from repro.fleet.job import JobSpec, SIZE_CLASSES  # noqa: F401
from repro.fleet.policies import (DEFRAG_POLICIES,  # noqa: F401
                                  PLACEMENT_POLICIES, PREEMPTION_POLICIES,
                                  DefragPolicy, PlacementPolicy,
                                  PreemptionPolicy)
from repro.fleet.scenarios import (SCENARIOS, Scenario,  # noqa: F401
                                   build_sim, golden_sim)
from repro.fleet.sim import FleetSim, SimConfig  # noqa: F401

# repro.fleet.trace is intentionally not re-exported here: it doubles as
# the `python -m repro.fleet.trace` CLI, and importing it from the package
# __init__ would trigger runpy's double-import warning on every CLI use.
