from repro.fleet.cluster import Cluster  # noqa: F401
from repro.fleet.job import JobSpec, SIZE_CLASSES  # noqa: F401
from repro.fleet.policies import (DEFRAG_POLICIES,  # noqa: F401
                                  PLACEMENT_POLICIES, PREEMPTION_POLICIES,
                                  DefragPolicy, PlacementPolicy,
                                  PreemptionPolicy)
from repro.fleet.sim import FleetSim, SimConfig  # noqa: F401
