"""Workload generators: parameterized job populations matching the paper's
qualitative fleet shapes (Fig. 4 size-mix drift; train/serve/bulk phases;
per-arch Program Goodput from the roofline table when available)."""
from __future__ import annotations

import json
import pathlib
import random
from typing import Callable, Dict, List, Optional

from repro.configs import ARCH_IDS
from repro.fleet.job import JobSpec

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"

# chip-count choices per size class (powers of two: torus slices)
SIZE_CHIPS = {
    "small": [1, 2, 4, 8],
    "medium": [16, 32, 64],
    "large": [128, 256],
    "xl": [512, 1024],
}

# paper Fig. 4: the XL share grows over the year; these are the endpoints.
SIZE_MIX_EARLY = {"small": 0.45, "medium": 0.35, "large": 0.15, "xl": 0.05}
SIZE_MIX_LATE = {"small": 0.30, "medium": 0.30, "large": 0.22, "xl": 0.18}

PHASE_MIX = {"train": 0.55, "serve": 0.30, "bulk_inference": 0.15}


def roofline_pg_table() -> Dict[str, float]:
    """Per-arch PG seeds from the dry-run roofline table (if present)."""
    out: Dict[str, float] = {}
    tbl = RESULTS / "roofline" / "table.json"
    if tbl.exists():
        for row in json.loads(tbl.read_text()):
            if row.get("shape") == "train_4k":
                out[row["arch"]] = max(0.05, min(0.95, row.get("pg_overlap", 0.4)))
    return out


def make_warp(intensity: Callable[[float], float], span: float,
              grid: int = 512) -> Callable[[float], float]:
    """Build ``u -> t`` mapping uniform draws in [0, span) onto an
    inhomogeneous arrival process with the given intensity profile, by
    inverting the normalized cumulative intensity on a fixed grid (built
    once here; each call is just a binary search + interpolation).

    Deterministic (no rng draws): scenario arrival modulation warps the
    *same* uniform stream the default workload consumes, so switching a
    modulation on cannot perturb any other seeded random stream — the
    determinism contract the trace record/replay tests rely on.
    """
    dt = span / grid if span > 0 else 0.0
    cum = [0.0]
    for i in range(grid):
        cum.append(cum[-1] + max(0.0, intensity((i + 0.5) * dt)) * dt)
    total = cum[-1]

    def warp(u: float) -> float:
        if span <= 0:
            return 0.0
        if total <= 0.0:
            return u
        target = (u / span) * total
        # binary search the bracketing grid cell, interpolate linearly
        lo, hi = 0, grid
        while lo + 1 < hi:
            mid = (lo + hi) // 2
            if cum[mid] <= target:
                lo = mid
            else:
                hi = mid
        cell = cum[lo + 1] - cum[lo]
        frac = (target - cum[lo]) / cell if cell > 0 else 0.0
        return min(span, (lo + frac) * dt)

    return warp


def warp_times(u: float, intensity: Callable[[float], float], span: float,
               grid: int = 512) -> float:
    """One-shot convenience over :func:`make_warp` (grid rebuilt per call —
    prefer ``make_warp`` inside loops)."""
    return make_warp(intensity, span, grid)(u)


def _pick(rng: random.Random, mix: Dict[str, float]) -> str:
    r = rng.random()
    acc = 0.0
    for k, v in mix.items():
        acc += v
        if r <= acc:
            return k
    return k  # noqa: B023 — last key


def generate_jobs(n_jobs: int, horizon: float, seed: int = 0,
                  size_mix: Optional[Dict[str, float]] = None,
                  async_checkpoint: bool = False,
                  compile_cache: bool = False,
                  framework_mix: float = 0.7,
                  pg_table: Optional[Dict[str, float]] = None,
                  capacity_chips: Optional[int] = None,
                  target_load: float = 0.70,
                  arrival_profile: Optional[Callable[[float], float]] = None
                  ) -> List[JobSpec]:
    """Poisson arrivals over [0, 0.8*horizon) with the given size mix.

    When ``capacity_chips`` is given, per-job work is rescaled so aggregate
    demand is ``target_load`` of fleet capacity — production fleets run
    below saturation (headroom for priority jobs, paper §3.2), and SG>95%
    (Fig. 16) is only achievable in that regime.

    ``arrival_profile`` is an intensity function over absolute sim time
    (diurnal/bursty load, ``repro.fleet.scenarios``): uniform arrival draws
    are warped through its inverse CDF, leaving every other random choice
    (sizes, archs, work, ...) byte-identical to the unmodulated workload.
    """
    rng = random.Random(seed)
    pg_table = pg_table if pg_table is not None else roofline_pg_table()
    jobs: List[JobSpec] = []
    for i in range(n_jobs):
        sc = _pick(rng, size_mix or SIZE_MIX_EARLY)
        chips = rng.choice(SIZE_CHIPS[sc])
        phase = _pick(rng, PHASE_MIX)
        arch = rng.choice(ARCH_IDS)
        # work sized so jobs run hours-to-days
        wall_target = rng.uniform(2, 30) * 3600 * (0.5 if sc == "small" else 1)
        work = wall_target * chips
        fw = "jax-pathways" if rng.random() < framework_mix else "multi-client"
        jobs.append(JobSpec(
            job_id=f"job{i:05d}",
            chips=chips,
            work=work,
            phase_kind=phase,
            arch=arch,
            priority={"small": 1, "medium": 1, "large": 2, "xl": 3}[sc]
            + (1 if phase == "serve" else 0),
            framework=fw,
            checkpoint_interval=rng.uniform(300, 900),
            checkpoint_write=rng.uniform(15, 60) * (chips / 64) ** 0.5,
            async_checkpoint=async_checkpoint,
            compile_cache_hit=compile_cache,
            init_time=rng.uniform(60, 240) * (1 + 0.3 * (chips > 256)),
            data_stall_frac=rng.uniform(0.01, 0.08),
            pg=pg_table.get(arch, rng.uniform(0.25, 0.6)),
            elastic=(phase == "train" and sc in ("medium", "large")),
            # mid-size training jobs run as 2-slice gangs (multi-slice over
            # DCN): a slice failure degrades/refills instead of killing the
            # job.  Deterministic rule — no rng draw, so the stream stays
            # byte-identical to pre-gang workloads.
            n_slices=2 if (phase == "train" and 32 <= chips <= 256) else 1,
            arrival=rng.uniform(0, 0.8 * horizon),
        ))
    if arrival_profile is not None:
        warp = make_warp(arrival_profile, 0.8 * horizon)
        jobs = [dataclasses_replace(j, arrival=warp(j.arrival))
                for j in jobs]
    if capacity_chips is not None:
        demand = sum(j.work for j in jobs)
        cap = capacity_chips * horizon * target_load
        scale = cap / demand if demand > 0 else 1.0
        jobs = [dataclasses_replace_work(j, j.work * scale) for j in jobs]
    return jobs


def dataclasses_replace(j: JobSpec, **kw) -> JobSpec:
    import dataclasses

    return dataclasses.replace(j, **kw)


def dataclasses_replace_work(j: JobSpec, work: float) -> JobSpec:
    return dataclasses_replace(j, work=work)
