"""Pluggable scheduler policies (paper §5.3, Fig. 16 ablations).

``FleetSim`` used to hardcode one scheduling strategy (topology-aware
best-fit, MEDIUM-victim preemption with XL protection, drain-based
defragmentation).  This module extracts the three decision points into
strategy objects injected via ``SimConfig``, so Fig. 16-style ablations
become policy sweeps instead of bool flags:

  * :class:`PlacementPolicy` — which pod a sub-pod job lands in
    (``best_fit`` / ``first_fit`` / ``spread``);
  * :class:`PreemptionPolicy` — which victims are evicted for a
    higher-priority arrival (``protect_xl`` / ``priority_only`` / ``none``);
  * :class:`DefragPolicy` — how fragmentation is repaired
    (``drain_for_xl`` / ``migrate_small`` / ``none``).

Policies only *choose* (pods to drain, victims to evict, orderings);
``FleetSim`` performs the state mutations — stop/requeue/restart book-
keeping stays in one place so the Interval ledger semantics cannot drift
between policies.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple, Type, Union

from repro.fleet.cluster import REPAIR_TAG, owner_of

# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------


class PlacementPolicy:
    """Orders candidate pods for a sub-pod allocation.

    ``pod_key(cluster)`` returns a sort key over ``_BuddyPod`` objects;
    the lowest-keyed candidate that fits wins.  Multi-pod (XL) jobs always
    take whole empty pods and bypass placement ordering.
    """

    name = "base"

    def pod_key(self, cluster):
        raise NotImplementedError

    def alloc(self, cluster, job_id: str, chips: int,
              exclude: Tuple[int, ...] = ()):
        return cluster.alloc(job_id, chips, exclude=exclude,
                             pod_key=self.pod_key(cluster))


class BestFitPlacement(PlacementPolicy):
    """Tightest pod first (defragmentation-friendly; the paper's default).
    Ties break toward the busier pod, concentrating load."""

    name = "best_fit"

    def pod_key(self, cluster):
        occ = getattr(cluster, "pod_occupancy", None)
        if occ is not None:   # indexed cluster: O(1) occupancy counts
            return lambda p: (p.largest_slice(), -occ(p.pod_id))
        return lambda p: (p.largest_slice(), -len(cluster.pod_jobs(p.pod_id)))


class FirstFitPlacement(PlacementPolicy):
    """Lowest pod id that fits — the no-information baseline."""

    name = "first_fit"

    def pod_key(self, cluster):
        return lambda p: p.pod_id


class SpreadPlacement(PlacementPolicy):
    """Emptiest pod first: balances load, maximizes fragmentation — the
    anti-pattern the paper's Myth 1 (capacity != availability) warns about."""

    name = "spread"

    def pod_key(self, cluster):
        return lambda p: (-p.free_chips(), p.pod_id)


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------


class PreemptionPolicy:
    """Chooses eviction victims for a job that cannot be placed.

    ``victims_for(sim, job)`` returns job-ids to evict (the sim performs
    the evictions and the retry alloc), or ``None`` when the policy
    declines.  ``protects_xl`` is consulted by the XL whole-pod path.
    """

    name = "base"
    protects_xl = False

    def victims_for(self, sim, job) -> Optional[List[str]]:
        raise NotImplementedError

    # -- shared helpers ----------------------------------------------------
    def _sub_pod_victims(self, sim, job, rank_fn) -> Optional[List[str]]:
        """Greedy victim pick for sub-pod jobs, ordered by ``rank_fn``."""
        eff = sim._eff_priority(job.spec.job_id)
        candidates = []
        for j in sim.running:
            v = sim.jobs[j]
            if v.spec.priority > eff - sim.cfg.preempt_gap:
                continue
            if v.preemptions >= 2:      # eviction-churn guard
                continue
            if self.protects_xl and v.spec.size_class == "xl":
                continue
            candidates.append((rank_fn(v), v.spec.chips, j))
        if not candidates:
            return None
        candidates.sort()
        victims, freed = [], 0
        for _, chips, j in candidates:
            victims.append(j)
            freed += chips
            if freed >= job.spec.chips:
                return victims
        return victims if freed >= job.spec.chips else None

    def _whole_pod_victims(self, sim, job) -> Optional[List[str]]:
        """Whole-pod eviction for multi-pod jobs: pods whose occupants are
        all evictable, cheapest displaced chips first."""
        need = -(-job.spec.chips // sim.cfg.pod_size)
        eff = sim._eff_priority(job.spec.job_id)
        usable = []
        for pod in sim.cluster.pods:
            # gang slices allocate per-slice under "<job>#s<k>"; evicting
            # any slice displaces the whole gang, so dedup to owners
            owners: List[str] = []
            for alloc_id in sim.cluster.pod_jobs(pod.pod_id):
                o = owner_of(alloc_id)
                if o not in owners:
                    owners.append(o)
            cost, ok = 0.0, True
            for j in owners:
                if j not in sim.jobs:        # maintenance reservation
                    ok = False
                    break
                v = sim.jobs[j]
                if v.spec.chips > sim.cfg.pod_size \
                        and v.spec.n_slices == 1:     # single-slice XL: immovable
                    ok = False
                    break
                if v.spec.priority >= eff:   # never displace higher priority
                    ok = False
                    break
                cost += v.spec.chips
            if ok:
                usable.append((cost, pod.pod_id, owners))
        if len(usable) < need:
            return None
        usable.sort()
        victims: List[str] = []
        for _, _, owners in usable[:need]:
            for j in owners:
                if j not in victims:         # a gang may span chosen pods
                    victims.append(j)
        return victims


class ProtectXLPreemption(PreemptionPolicy):
    """The paper's policy: never evict XL (restart cascades are ruinous),
    prefer MEDIUM victims (SMALL finish soon anyway, LARGE next)."""

    name = "protect_xl"
    protects_xl = True
    _RANK = {"medium": 0, "large": 1, "small": 2, "xl": 3}

    def victims_for(self, sim, job):
        if job.spec.chips > sim.cfg.pod_size:
            return self._whole_pod_victims(sim, job)
        return self._sub_pod_victims(
            sim, job, lambda v: self._RANK[v.spec.size_class])


class PriorityOnlyPreemption(PreemptionPolicy):
    """Pure priority ordering, no size-class protection — the ablation
    showing why unprotected XL jobs crater per-class SG (Fig. 16)."""

    name = "priority_only"
    protects_xl = False

    def victims_for(self, sim, job):
        if job.spec.chips > sim.cfg.pod_size:
            return self._whole_pod_victims(sim, job)
        return self._sub_pod_victims(
            sim, job, lambda v: (v.spec.priority, v.spec.chips))


class NoPreemption(PreemptionPolicy):
    """Arrivals wait for capacity; nothing is ever evicted."""

    name = "none"
    protects_xl = True          # vacuously: nothing is evicted

    def victims_for(self, sim, job):
        return None


# ---------------------------------------------------------------------------
# defragmentation
# ---------------------------------------------------------------------------


class DefragPolicy:
    """Repairs fragmentation.  Two hooks:

    * ``drain_pods(sim)`` — before each scheduling pass: pods to reserve
      for a queued multi-pod job (occupants get migrated out by the sim);
    * ``migration_victim(sim, job)`` — when ``job`` cannot fit: a running
      job to checkpoint-migrate so a slice coalesces, or ``None``.
    """

    name = "base"

    def drain_pods(self, sim) -> Tuple[int, ...]:
        return ()

    def migration_victim(self, sim, job) -> Optional[str]:
        return None

    # -- shared helpers ----------------------------------------------------
    @staticmethod
    def _xl_drain_target(sim) -> Tuple[int, ...]:
        """Emptiest pods covering the largest queued multi-pod job.

        Only *serviceable* pods count: pods under a maintenance
        reservation (sentinel allocations with no backing job) can be
        neither drained nor granted, and a job needing more pods than
        are currently serviceable is ignored — draining for a job that
        cannot fit would exclude every pod from scheduling and deadlock
        the fleet (found by the tiny golden-trace configs, where the
        workload can emit cluster-sized requests).

        The trigger keys on *slice* width: only jobs whose slices need
        whole pods benefit from whole-pod drains.  A gang whose slices
        are sub-pod places into fragmented pods — and respects the drain
        exclusion, so draining for it would starve its own placement.
        """
        pod_size = sim.cfg.pod_size
        reserved = getattr(sim.cluster, "reserved_pods", None)
        if reserved is None:
            # maintenance sentinels only: repair holds are sub-pod and do
            # not reserve their pod (mirrors the indexed cluster's
            # ``reserved_pods``, which tracks ``reserve_pod`` tags alone)
            reserved = {a.pod for tag, a in sim.cluster.allocations.items()
                        if owner_of(tag) not in sim.jobs and a.pod >= 0
                        and not tag.startswith(REPAIR_TAG)}
        serviceable = [p for p in sim.cluster.pods
                       if p.pod_id not in reserved]
        max_chips = len(serviceable) * pod_size
        xl_need = max((sim.jobs[j].spec.chips // pod_size
                       for j in sim.queue
                       if pod_size < sim.jobs[j].spec.slice_chips
                       and sim.jobs[j].spec.chips <= max_chips),
                      default=0)
        if xl_need == 0:
            return ()
        by_emptiness = sorted(serviceable, key=lambda p: -p.free_chips())
        return tuple(p.pod_id for p in by_emptiness[:xl_need])

    @staticmethod
    def _smallest_running(sim) -> Optional[str]:
        idx = sim.__dict__.get("_small_running")
        if idx is not None:
            # vectorized engine: chips -> {job_id: None} buckets over the
            # running "small" jobs, each bucket in running-dict insertion
            # order — the first job of the lowest non-empty bucket is the
            # same first-minimal job the full scan below would pick
            best = None
            best_chips = 0
            for c, bucket in idx.items():
                if bucket and (best is None or c < best_chips):
                    best = bucket
                    best_chips = c
            if best is None:
                return None
            return next(iter(best))
        victims = [j for j in sim.running
                   if sim.jobs[j].spec.size_class == "small"]
        if not victims:
            return None
        return min(victims, key=lambda j: sim.jobs[j].spec.chips)


class DrainForXLDefrag(DefragPolicy):
    """The paper's policy: reserve + drain pods for queued XL work, and
    migrate small jobs to make room when a sub-pod job is stuck."""

    name = "drain_for_xl"

    def drain_pods(self, sim):
        return self._xl_drain_target(sim)

    def migration_victim(self, sim, job):
        if job.spec.chips > sim.cfg.pod_size:
            return None
        return self._smallest_running(sim)


class MigrateSmallDefrag(DefragPolicy):
    """Point defragmentation only: migrate small jobs on demand, never
    drain whole pods (XL jobs must find naturally-empty pods)."""

    name = "migrate_small"

    def migration_victim(self, sim, job):
        if job.spec.chips > sim.cfg.pod_size:
            return None
        return self._smallest_running(sim)


class NoDefrag(DefragPolicy):
    """Fragmentation is never repaired — the Myth 1 baseline."""

    name = "none"


# ---------------------------------------------------------------------------
# registry / resolution
# ---------------------------------------------------------------------------

PLACEMENT_POLICIES: Dict[str, Type[PlacementPolicy]] = {
    c.name: c for c in (BestFitPlacement, FirstFitPlacement, SpreadPlacement)}
PREEMPTION_POLICIES: Dict[str, Type[PreemptionPolicy]] = {
    c.name: c for c in (ProtectXLPreemption, PriorityOnlyPreemption,
                        NoPreemption)}
DEFRAG_POLICIES: Dict[str, Type[DefragPolicy]] = {
    c.name: c for c in (DrainForXLDefrag, MigrateSmallDefrag, NoDefrag)}


def _resolve(spec, registry, kind):
    if isinstance(spec, str):
        try:
            return registry[spec]()
        except KeyError:
            raise ValueError(
                f"unknown {kind} policy {spec!r}; "
                f"choose from {sorted(registry)}") from None
    return spec


def resolve_placement(spec: Union[str, PlacementPolicy]) -> PlacementPolicy:
    return _resolve(spec, PLACEMENT_POLICIES, "placement")


def resolve_preemption(spec: Union[str, PreemptionPolicy]) -> PreemptionPolicy:
    return _resolve(spec, PREEMPTION_POLICIES, "preemption")


def resolve_defrag(spec: Union[str, DefragPolicy]) -> DefragPolicy:
    return _resolve(spec, DEFRAG_POLICIES, "defrag")


# named policy combinations (shared by the advisor's scheduler knob, the
# adaptive controller's rescue rule, and the controller benchmark): the
# paper's §5.3 scheduler vs. the no-information baseline
PAPER_COMBO: Dict[str, str] = {"placement": "best_fit",
                               "preemption": "protect_xl",
                               "defrag": "drain_for_xl"}
NAIVE_COMBO: Dict[str, str] = {"placement": "spread",
                               "preemption": "priority_only",
                               "defrag": "none"}
