"""Discrete-event fleet simulator (paper §3.2 / §5.3).

Event-driven: job arrivals, completions, chip failures, preemptions.
Per-run-segment accounting is analytic (checkpoint cycles are folded into a
productive-rate factor) so a month of fleet time with thousands of jobs
simulates in milliseconds while emitting the exact same Interval ledger the
MPG metric consumes.

Scheduler policy (paper §5.3, Fig. 16) is *pluggable* — strategy objects
from ``repro.fleet.policies`` injected via ``SimConfig``; the defaults
reproduce the paper's policy:
  * topology-aware best-fit placement into buddy-allocated pod slices;
  * preemption prefers MEDIUM victims — evicting XL jobs cascades (huge
    restart cost), and SMALL jobs finish soon anyway;
  * defragmentation: when the queue head cannot fit due to fragmentation,
    small movable jobs are migrated (checkpoint-resume) to coalesce slices.

Accounting streams into a ``repro.core.ledger.GoodputLedger`` (shared
across layers/clusters when one is injected); ``sim.intervals`` remains
available when ``SimConfig.retain_intervals`` is on (the default).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
from collections import defaultdict
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple, Union

from repro.core.goodput import Interval, Layer, Phase, generation_pg_weights
from repro.core.ledger import GoodputLedger
from repro.fleet.cluster import REPAIR_TAG, SLICE_SEP, Cluster, owner_of
from repro.fleet.job import JobRuntime, JobSpec
from repro.fleet.policies import (DefragPolicy, PlacementPolicy,
                                  PreemptionPolicy, resolve_defrag,
                                  resolve_placement, resolve_preemption)
from repro.parallel.reshard import reshard_seconds

if TYPE_CHECKING:                     # import cycle: scenarios builds sims
    from repro.fleet.scenarios import Scenario

MAINT_TAG = "__maint__"               # sentinel allocation id prefix for
                                      # drained (in-maintenance) pods

# Per-generation repair-time distributions (ROADMAP repair-realism item):
# ``SimConfig.slice_repair_s`` stays the fleet-wide *scale* knob, but the
# actual window is that scale times a lognormal multiplier drawn on the
# sim's dedicated repair stream — older generations take longer to source
# parts for and vary more; the newest generation repairs tightest.  The
# (mu, sigma) pairs are of the underlying normal, so the median multiplier
# is e**mu (~1.0: the configured scale remains the typical repair).
REPAIR_LOGNORMAL: Dict[str, Tuple[float, float]] = {
    "tpu-v4": (0.15, 0.60),
    "tpu-v5e": (0.0, 0.45),
    "tpu-v5p": (-0.10, 0.35),
}
_REPAIR_LOGNORMAL_DEFAULT = (0.0, 0.45)


@dataclasses.dataclass
class SimConfig:
    n_pods: int = 8
    pod_size: int = 256
    horizon: float = 7 * 24 * 3600.0
    chip_mtbf: float = 150.0 * 24 * 3600     # seconds per chip failure
    seed: int = 0
    xl_assembly_per_pod: float = 60.0        # PARTIAL time per extra pod
    defrag_migration_cost: float = 45.0      # seconds to move a small job
    preempt_protect_xl: bool = True          # legacy alias: False selects
                                             # the "priority_only" policy
    async_snapshot_pause: float = 1.5        # device pause per async ckpt
    aging_hours: float = 6.0                 # queue aging: +1 priority / N h
    preempt_gap: float = 1.0                 # min priority advantage to evict
    drain_cap: int = 4                       # max migrations per event
    # hardware repair window: a failed slice's chips stay out of service
    # this many seconds before returning to the allocator.  0 (default)
    # models instant replacement — the failed chips free immediately, so
    # a rigid gang's refill is usually granted on the spot.  >0 makes
    # replacement scarce: rigid gangs hold survivors idle (gang_stall)
    # while elastic gangs keep computing degraded — the resiliency trade
    # benchmarks/resilience.py measures.
    slice_repair_s: float = 0.0
    # pluggable scheduler policies (name or strategy object; see
    # repro.fleet.policies for the registries)
    placement: Union[str, PlacementPolicy] = "best_fit"
    preemption: Union[str, PreemptionPolicy] = "protect_xl"
    defrag: Union[str, DefragPolicy] = "drain_for_xl"
    # accounting
    retain_intervals: bool = True            # keep raw Interval list
    ledger_window: float = 3600.0            # MPG time-series bucket (s)
    # telemetry sampling cadence (seconds); None keeps the historical
    # horizon/200 coupling — set explicitly for year-horizon runs so the
    # windowed-series resolution does not silently change with horizon
    sample_dt: Optional[float] = None
    # event-core engine: "vectorized" (default; same decisions + rng
    # streams, batched accounting and memoized scheduling) or "reference"
    # (the legacy per-event engine, the equivalence-gate baseline)
    engine: str = "vectorized"
    # fleet conditions (diurnal load, maintenance drains, failure bursts,
    # heterogeneous pod generations) — see repro.fleet.scenarios
    scenario: Optional["Scenario"] = None

    def __post_init__(self):
        if self.engine not in ("reference", "vectorized"):
            raise ValueError(
                f"SimConfig.engine must be 'reference' or 'vectorized', "
                f"got {self.engine!r}")
        if self.sample_dt is not None and not self.sample_dt > 0:
            raise ValueError(
                f"SimConfig.sample_dt must be > 0, got {self.sample_dt!r}")
        if self.slice_repair_s < 0:
            raise ValueError(f"SimConfig.slice_repair_s must be >= 0, "
                             f"got {self.slice_repair_s!r}")


class FleetSim:
    def __new__(cls, cfg: SimConfig, *args, **kwargs):
        # `FleetSim(cfg)` honours cfg.engine: the vectorized subclass is
        # decision-identical (same policies, same rng streams) but runs
        # the hot path through caches and batched ledger ingest.  Explicit
        # subclass construction bypasses the dispatch.
        if cls is FleetSim and cfg.engine == "vectorized":
            from repro.fleet.vectorized import VectorizedFleetSim
            return super().__new__(VectorizedFleetSim)
        return super().__new__(cls)

    def __init__(self, cfg: SimConfig, ledger: Optional[GoodputLedger] = None,
                 keep_intervals: Optional[bool] = None):
        """``keep_intervals`` overrides ``cfg.retain_intervals`` for the
        auto-created ledger — the opt-out for month-scale attribution
        runs that must stay O(1) memory (ignored when a shared ``ledger``
        is injected; its own retention setting wins)."""
        self.cfg = cfg
        self.cluster = self._make_cluster(cfg)
        self.rng = random.Random(cfg.seed)
        self.now = 0.0
        self.events: List[Tuple[float, int, str, str]] = []
        self._seq = 0
        self.jobs: Dict[str, JobRuntime] = {}
        self.queue: List[str] = []
        self.running: Dict[str, dict] = {}     # job_id -> segment info
        self.telemetry: List[dict] = []
        self._epoch: Dict[str, int] = defaultdict(int)
        self._queued_since: Dict[str, float] = {}
        # jobs whose current wait is preemption/failure-induced: that wait is
        # PARTIAL (counts against per-class SG, paper Fig. 16) rather than
        # initial QUEUED (a fleet-capacity matter, not a per-job one).
        self._requeued: set = set()
        # gang bookkeeping: live slice-allocation ids per job (single-slice
        # jobs allocate under their bare id), a monotonic per-job slice
        # counter (dead slice ids are never reused), and rigid gangs whose
        # survivors hold their allocation while waiting for a replacement
        # slice ({"t0": wait start})
        self._slices: Dict[str, List[str]] = {}
        self._slice_seq: Dict[str, int] = defaultdict(int)
        self._gang_wait: Dict[str, dict] = {}
        self._repair_seq = 0                 # monotonic repair sentinel ids
        # repair-time sampling stream (drawn only under a repair window,
        # so the default slice_repair_s=0 stays byte-identical)
        self._repair_rng = random.Random(f"{cfg.seed}:repair")
        # online adaptive controller (repro.fleet.controller): None means
        # static policies for the whole run — the historical behaviour
        self.controller = None
        # fleet-wide elastic-resize override: None defers to each job's
        # spec.elastic flag; True/False is the controller forcing it
        self._elastic_override: Optional[bool] = None
        # running elastic jobs currently below their submitted shape, in
        # degradation order (a dict, not a set: iteration order must be
        # deterministic and identical across engines)
        self._degraded: Dict[str, None] = {}
        # scheduler policies (cfg.preempt_protect_xl=False is the legacy
        # spelling of the priority_only ablation)
        preemption = cfg.preemption
        if preemption == "protect_xl" and not cfg.preempt_protect_xl:
            preemption = "priority_only"
        self.placement = resolve_placement(cfg.placement)
        self.preemption = resolve_preemption(preemption)
        self.defrag = resolve_defrag(cfg.defrag)
        # scenario conditions (repro.fleet.scenarios).  Randomness that a
        # scenario introduces runs on its own seeded stream so composing a
        # modifier cannot perturb the base failure/workload streams — the
        # determinism audit's per-component-rng rule.
        self.pod_generation: List[str] = ["tpu-v5e"] * cfg.n_pods
        self.pod_factor: List[float] = [1.0] * cfg.n_pods
        self._mtbf_factor = 1.0
        self._burst_rng = random.Random(f"{cfg.seed}:bursts")
        self._maint_depth: Dict[int, int] = defaultdict(int)
        scn = cfg.scenario
        if scn is not None:
            self._mtbf_factor = scn.mtbf_factor
            if scn.pod_generations:
                gens = [scn.pod_generations[i % len(scn.pod_generations)]
                        for i in range(cfg.n_pods)]
                weights = generation_pg_weights(gens)
                self.pod_generation = gens
                self.pod_factor = [weights[g] for g in gens]
            for mw in scn.maintenance:
                pid = mw.pod % cfg.n_pods
                self._push(mw.start_frac * cfg.horizon, "maint_start",
                           str(pid))
                self._push(mw.end_frac * cfg.horizon, "maint_end", str(pid))
            for idx, burst in enumerate(scn.bursts):
                self._push(burst.at_frac * cfg.horizon, "burst", str(idx))
        # accounting: one streaming ledger, optionally shared fleet-wide
        retain = (cfg.retain_intervals if keep_intervals is None
                  else keep_intervals)
        self.ledger = ledger if ledger is not None else GoodputLedger(
            window=cfg.ledger_window,
            retain_intervals=retain)
        self.ledger.add_capacity(self.capacity_chip_time)

    def _make_cluster(self, cfg: SimConfig) -> Cluster:
        """Engine hook: the vectorized engine substitutes an indexed,
        cache-backed cluster with identical allocation behaviour."""
        return Cluster(cfg.n_pods, cfg.pod_size)

    @property
    def intervals(self) -> List[Interval]:
        """The raw event stream (requires ``retain_intervals``)."""
        if self.ledger.intervals is None:
            raise AttributeError(
                "interval retention is off (SimConfig.retain_intervals="
                "False); use the streaming ledger reports instead")
        return self.ledger.intervals

    # ---- event plumbing -------------------------------------------------
    def _push(self, t: float, kind: str, payload: str):
        self._seq += 1
        heapq.heappush(self.events, (t, self._seq, kind, payload))

    def submit(self, spec: JobSpec):
        self.jobs[spec.job_id] = JobRuntime(spec)
        self._push(spec.arrival, "arrival", spec.job_id)

    # ---- interval ledger -------------------------------------------------
    def _emit(self, job: JobRuntime, phase: Phase, t0: float, t1: float,
              layer: Layer, gen: Optional[Tuple[str, float]] = None,
              chips: Optional[int] = None):
        """``chips`` overrides the spec width for intervals narrower than
        the job (a rigid gang's surviving slices stalling on a dead one)."""
        if t1 <= t0:
            return
        s = job.spec
        segment = {
            "size_class": s.size_class, "phase_kind": s.phase_kind,
            "arch": s.arch, "framework": s.framework,
            "ckpt": "async" if s.async_checkpoint else "sync",
            "emitter": "fleet", "layer": layer.value,
        }
        pg = s.pg
        if gen is not None:
            # heterogeneous fleet: ideal time normalizes to the best
            # generation present, so STEP on a slower pod carries a lower
            # effective PG (paper §3.1 / §4.2)
            segment["generation"] = gen[0]
            pg = s.pg * gen[1]
        self.ledger.emit(job_id=s.job_id, phase=phase, t0=t0, t1=t1,
                         chips=s.chips if chips is None else chips,
                         segment=segment, pg=pg)

    def _gen_of(self, job_id: str) -> Tuple[str, float]:
        """(generation name, PG weight) of a job's current allocation;
        multi-pod and multi-slice allocations average their pods'
        weights."""
        pods: List[int] = []
        for sid in self._slices.get(job_id, ()):
            alloc = self.cluster.allocations.get(sid)
            if alloc is None:
                continue
            if alloc.pod >= 0:
                pods.append(alloc.pod)
            else:
                pods.extend(alloc.pods)
        if not pods:
            return "tpu-v5e", 1.0
        gens = {self.pod_generation[p] for p in pods}
        factor = sum(self.pod_factor[p] for p in pods) / len(pods)
        return (gens.pop() if len(gens) == 1 else "mixed"), factor

    # ---- productive-rate model -------------------------------------------
    def _rates(self, s: JobSpec) -> Tuple[float, float, float]:
        """Fractions of allocated wall time in (step, ckpt, stall)."""
        if s.async_checkpoint:
            ckpt_overhead = self.cfg.async_snapshot_pause / s.checkpoint_interval
        else:
            ckpt_overhead = s.checkpoint_write / s.checkpoint_interval
        stall = s.effective_stall()
        # floor: even a pathologically stalled job makes some progress
        step = max(0.02, 1.0 - ckpt_overhead - stall)
        stall = max(0.0, min(stall, 1.0 - step - ckpt_overhead))
        return step, ckpt_overhead, stall

    # ---- live control hooks ----------------------------------------------
    def _job_elastic(self, spec: JobSpec) -> bool:
        """Whether ``spec`` resizes elastically right now: the spec's own
        flag unless the adaptive controller forced a fleet-wide override."""
        ov = self._elastic_override
        return spec.elastic if ov is None else ov

    def set_policies(self, placement=None, preemption=None,
                     defrag=None) -> None:
        """Swap live scheduler policy objects mid-run (the adaptive
        controller's switch hook; names or strategy objects, None keeps
        the current one).  Engine subclasses re-derive policy-dependent
        caches here."""
        if placement is not None:
            self.placement = resolve_placement(placement)
        if preemption is not None:
            self.preemption = resolve_preemption(preemption)
        if defrag is not None:
            self.defrag = resolve_defrag(defrag)

    def attach_controller(self, controller) -> None:
        """Register an online adaptive controller and schedule its first
        decision boundary; the run loop hands it the sim on every timed
        ``control`` event (see ``repro.fleet.controller``)."""
        self.controller = controller
        self._push(controller.decide_every_s, "control", "")

    def _control_sync(self) -> None:
        """Engine hook: bring the ledger/waterfall state current before a
        controller observation.  The reference engine emits per event, so
        there is nothing to do; the vectorized engine flushes its columnar
        buffers here so both engines decide on identical state."""

    def _on_control(self, t: float) -> None:
        self._control_sync()
        self.controller.on_boundary(self)
        nxt = t + self.controller.decide_every_s
        if nxt <= self.cfg.horizon:
            self._push(nxt, "control", "")

    # ---- scheduling ------------------------------------------------------
    def _eff_priority(self, job_id: str) -> float:
        """Priority with aging: +1 level per 6h queued (starvation guard)."""
        base = self.jobs[job_id].spec.priority
        if job_id in self._requeued:
            base += 1.0   # preempted/failed jobs resume ahead of new work
        waited = self.now - self._queued_since.get(job_id, self.now)
        return base + waited / (self.cfg.aging_hours * 3600.0)

    # ---- gang-aware allocation -------------------------------------------
    def _place(self, alloc_id: str, chips: int,
               exclude: tuple = ()):
        """Engine hook: one placement-policy allocation (the vectorized
        engine substitutes its failure-memoized variant)."""
        return self.placement.alloc(self.cluster, alloc_id, chips,
                                    exclude=exclude)

    def _alloc_job(self, job_id: str, spec: JobSpec,
                   exclude: tuple = ()) -> bool:
        """Allocate every slice of ``spec`` (one allocation under the bare
        id for single-slice jobs); rolls back on partial failure."""
        if spec.n_slices == 1:
            if self._place(job_id, spec.chips, exclude) is None:
                return False
            self._slices[job_id] = [job_id]
            return True
        per = spec.slice_chips
        ids: List[str] = []
        for _ in range(spec.n_slices):
            self._slice_seq[job_id] += 1
            sid = f"{job_id}{SLICE_SEP}{self._slice_seq[job_id]}"
            if self._place(sid, per, exclude) is None:
                for done in ids:
                    self.cluster.release(done)
                return False
            ids.append(sid)
        self._slices[job_id] = ids
        return True

    def _release_job(self, job_id: str):
        self._degraded.pop(job_id, None)
        for sid in self._slices.pop(job_id, (job_id,)):
            self.cluster.release(sid)

    def _evict_gang_wait(self, job_id: str):
        """Close a rigid gang's replacement wait: book the survivors' hold
        as hardware-layer IDLE (gang_stall), free everything, requeue."""
        w = self._gang_wait.pop(job_id)
        job = self.jobs[job_id]
        s = job.spec
        self._emit(job, Phase.IDLE, w["t0"], self.now,
                   layer=Layer.HARDWARE, chips=s.chips - s.slice_chips)
        self._release_job(job_id)
        self._queued_since[job_id] = self.now
        self._requeued.add(job_id)
        self.queue.append(job_id)

    def _refill_gangs(self, drain: tuple):
        """Try to grant each waiting rigid gang its replacement slice; on
        success the survivors' wait books as hardware-layer IDLE and the
        gang restarts from checkpoint at full width."""
        for job_id in list(self._gang_wait):
            job = self.jobs[job_id]
            s = job.spec
            exclude = drain if s.slice_chips <= self.cfg.pod_size else ()
            self._slice_seq[job_id] += 1
            sid = f"{job_id}{SLICE_SEP}{self._slice_seq[job_id]}"
            if self._place(sid, s.slice_chips, exclude) is None:
                continue
            w = self._gang_wait.pop(job_id)
            self._slices[job_id].append(sid)
            self._emit(job, Phase.IDLE, w["t0"], self.now,
                       layer=Layer.HARDWARE, chips=s.chips - s.slice_chips)
            self._start_segment(job)

    def _retire_slice(self, sid: str):
        """Free a failed slice's hardware — immediately when repair is
        instant (``slice_repair_s == 0``, byte-identical to the historical
        behaviour), otherwise held under a repair sentinel until a timed
        ``repair`` event returns the chips to the allocator.  The window
        is ``slice_repair_s`` scaled by the failed slice's generation-
        specific lognormal draw (``REPAIR_LOGNORMAL``) on the dedicated
        repair stream."""
        repair = self.cfg.slice_repair_s
        if repair <= 0:
            self.cluster.release(sid)
            return
        repair *= self._sample_repair_factor(sid)
        self._repair_seq += 1
        tag = f"{REPAIR_TAG}{self._repair_seq}"
        self.cluster.retag(sid, tag)
        self._push(self.now + repair, "repair", tag)

    def _sample_repair_factor(self, sid: str) -> float:
        """Lognormal repair-time multiplier for the generation of the
        failed slice's (first) pod; must run before the slice is retagged
        (the allocation lookup goes away with the original id)."""
        alloc = self.cluster.allocations.get(sid)
        pod = 0
        if alloc is not None:
            pod = alloc.pod if alloc.pod >= 0 else alloc.pods[0]
        mu, sigma = REPAIR_LOGNORMAL.get(self.pod_generation[pod],
                                         _REPAIR_LOGNORMAL_DEFAULT)
        return self._repair_rng.lognormvariate(mu, sigma)

    def _regrow_elastic(self, drain: tuple):
        """Grow running degraded elastic jobs back toward their submitted
        shape as capacity frees (checkpoint-restart at the wider shape,
        paying the reshard transfer back up).

        Only runs under a repair window (``slice_repair_s > 0``): with
        instant repair the failed chips free on the spot, so a degraded
        job's own dead slice would be immediately re-grantable and the
        degrade/regrow pair would collapse into restart churn — the
        requeue-time regrow in :meth:`_sched_one` already covers that
        idealized regime."""
        if self.cfg.slice_repair_s <= 0 or not self._degraded:
            return
        for job_id in list(self._degraded):
            job = self.jobs[job_id]
            s = job.spec
            exclude = drain if s.slice_chips <= self.cfg.pod_size else ()
            if job.target_slices > 1:
                # gang: re-admit slices one at a time
                grown = False
                while job.spec.n_slices < job.target_slices:
                    self._slice_seq[job_id] += 1
                    sid = f"{job_id}{SLICE_SEP}{self._slice_seq[job_id]}"
                    if self._place(sid, s.slice_chips, exclude) is None:
                        break
                    if not grown:
                        grown = True
                        self._stop_segment(job, lost=False)  # ckpt-resume
                    self._slices[job_id].append(sid)
                    k = job.spec.n_slices + 1
                    job.spec = dataclasses.replace(
                        job.spec, chips=s.slice_chips * k, n_slices=k)
                if grown:
                    self._start_segment(job)
            else:
                # halved single-slice job: place the full shape first
                # (under a scratch id, so failure leaves the job
                # untouched), then swap allocations
                tmp = f"{job_id}{SLICE_SEP}grow"
                if self._place(tmp, job.target_chips, exclude) is None:
                    continue
                self._stop_segment(job, lost=False)          # ckpt-resume
                self.cluster.release(job_id)
                self.cluster.retag(tmp, job_id)
                job.spec = dataclasses.replace(s, chips=job.target_chips)
                self._start_segment(job)
            if job.spec.chips >= job.target_chips:
                self._degraded.pop(job_id, None)

    def _slice_failure(self, job: JobRuntime, rng: random.Random):
        """A hardware failure hits one slice of ``job`` (the whole job for
        single-slice specs).  Elastic gangs shed the dead slice and restart
        in place on the survivors (paying the reshard transfer); rigid
        gangs hold the survivors and wait for a replacement slice.  The
        dead slice's chips go to repair (:meth:`_retire_slice`)."""
        s = job.spec
        job_id = s.job_id
        job.failures += 1
        self._stop_segment(job, lost=True)       # hardware rollback
        if s.n_slices > 1:
            k = rng.randrange(s.n_slices)        # which slice died
            sid = self._slices[job_id].pop(k)
            self._retire_slice(sid)
            if job.remaining <= 0:
                self._release_job(job_id)
                return
            if self._job_elastic(s):
                # degrade: reshard onto the surviving slices, in place
                job.spec = dataclasses.replace(
                    s, chips=s.slice_chips * (s.n_slices - 1),
                    n_slices=s.n_slices - 1)
                self._start_segment(job)
                self._degraded[job_id] = None
            else:
                self._gang_wait[job_id] = {"t0": self.now}
            return
        self._degraded.pop(job_id, None)
        for sid in self._slices.pop(job_id, (job_id,)):
            self._retire_slice(sid)
        if job.remaining > 0:
            self._queued_since[job_id] = self.now
            self._requeued.add(job_id)
            self.queue.append(job_id)

    def _drain_for_xl(self) -> tuple:
        """When a multi-pod job queues, reserve + drain pods chosen by the
        defrag policy (the paper's defragmentation at pod granularity)."""
        drain = tuple(self.defrag.drain_pods(self))
        migrated = 0
        for pid in drain:
            seen = set()
            for alloc_id in list(self.cluster.pod_jobs(pid)):
                if migrated >= self.cfg.drain_cap:  # churn cap per event
                    break
                job_id = owner_of(alloc_id)
                if job_id not in self.jobs or job_id in seen:
                    continue   # maintenance reservation / other gang slice
                seen.add(job_id)
                v = self.jobs[job_id]
                if job_id in self._gang_wait:
                    self._evict_gang_wait(job_id)
                    migrated += 1
                    continue
                if v.spec.chips > 64:   # migrate only small/medium
                    continue
                self._stop_segment(v, lost=False)   # checkpoint-resume
                self._release_job(job_id)
                if self._alloc_job(job_id, v.spec, exclude=drain):
                    if v.spec.init_time != self.cfg.defrag_migration_cost:
                        v.spec = dataclasses.replace(
                            v.spec, init_time=self.cfg.defrag_migration_cost)
                    # a migration restart's INIT is scheduling-induced
                    self._start_segment(v, init_layer=Layer.SCHEDULING)
                else:
                    self._queued_since[job_id] = self.now
                    self._requeued.add(job_id)
                    self.queue.append(job_id)
                migrated += 1
        return drain

    def _sched_one(self, job: JobRuntime, drain: tuple) -> bool:
        """One queued job's placement attempt; shared verbatim by both
        engines (the vectorized engine substitutes ``_place``)."""
        s = job.spec
        job_id = s.job_id
        exclude = drain if s.slice_chips <= self.cfg.pod_size else ()
        requeued = job_id in self._requeued
        elastic = self._job_elastic(s)
        # regrow: a degraded elastic job first tries its submitted shape
        # (paying the reshard transfer back up on restart)
        if requeued and elastic and s.chips < job.target_chips:
            tgt = dataclasses.replace(s, chips=job.target_chips,
                                      n_slices=job.target_slices)
            if self._alloc_job(job_id, tgt, exclude):
                job.spec = tgt
                self._start_segment(job)
                return True
        if self._alloc_job(job_id, s, exclude):
            self._start_segment(job)
            if elastic and s.chips < job.target_chips:
                self._degraded[job_id] = None
            return True
        if requeued and elastic:
            # elastic resume: a preempted/failed job restarts degraded
            # instead of waiting for the full shape (paper §3.2's
            # utilization/stability trade; work rate scales with chips) —
            # gangs shed slices, single-slice jobs halve.
            if s.n_slices > 1:
                for k in range(s.n_slices - 1, 0, -1):
                    sub = dataclasses.replace(
                        s, chips=s.slice_chips * k, n_slices=k)
                    if self._alloc_job(job_id, sub, exclude):
                        job.spec = sub
                        self._start_segment(job)
                        self._degraded[job_id] = None
                        return True
            elif 2 <= s.chips <= self.cfg.pod_size:
                half = s.chips // 2
                sub = dataclasses.replace(s, chips=half)
                if self._alloc_job(job_id, sub, exclude):
                    job.spec = sub
                    self._start_segment(job)
                    self._degraded[job_id] = None
                    return True
        # defragmentation: migrate small jobs if that frees a slice
        if self._defrag_for(job):
            if self._alloc_job(job_id, job.spec):
                self._start_segment(job)
                return True
        # preemption for high-priority arrivals
        if self._preempt_for(job):
            if self._alloc_job(job_id, job.spec):
                self._start_segment(job)
                return True
        return False

    def _try_schedule(self):
        self.queue.sort(key=lambda j: (-self._eff_priority(j),
                                       self.jobs[j].spec.arrival))
        drain = self._drain_for_xl()
        self._refill_gangs(drain)
        self._regrow_elastic(drain)
        scheduled = []
        for job_id in list(self.queue):
            if self._sched_one(self.jobs[job_id], drain):
                scheduled.append(job_id)
        for j in scheduled:
            self.queue.remove(j)

    def _defrag_for(self, job: JobRuntime) -> bool:
        """Checkpoint-migrate the defrag policy's chosen victim so a slice
        can coalesce for ``job``."""
        victim = self.defrag.migration_victim(self, job)
        if victim is None:
            return False
        v = self.jobs[victim]
        self._stop_segment(v, lost=False)     # checkpoint-resume migration
        self._release_job(victim)
        # instant re-placement elsewhere (cost charged as INIT on restart)
        if self._alloc_job(victim, v.spec):
            # repeated migrations would replace with an identical spec —
            # only rebuild when init_time actually changes
            if v.spec.init_time != self.cfg.defrag_migration_cost:
                v.spec = dataclasses.replace(
                    v.spec, init_time=self.cfg.defrag_migration_cost)
            self._start_segment(v, init_layer=Layer.SCHEDULING)
            return True
        self._queued_since[victim] = self.now
        self._requeued.add(victim)
        self.queue.append(victim)
        return True

    def _preempt_for(self, job: JobRuntime) -> bool:
        """Evict the preemption policy's victims (it guarantees they free
        enough capacity or returns None); the sim books LOST work, requeues
        them, and the caller retries placement."""
        victims = self.preemption.victims_for(self, job)
        if not victims:
            return False
        self._evict_victims(victims)
        return True

    def _evict_victims(self, victims):
        """Shared eviction bookkeeping (both engines, both victim kinds):
        running victims roll back to their checkpoint; a rigid gang caught
        mid-replacement-wait closes its stall and requeues whole."""
        for j in victims:
            v = self.jobs[j]
            if j in self._gang_wait:
                self._evict_gang_wait(j)
                v.preemptions += 1
                continue
            # preemption rollback is a scheduling-layer loss, not hardware
            self._stop_segment(v, lost=True, lost_layer=Layer.SCHEDULING)
            self._release_job(j)
            v.preemptions += 1
            self._queued_since[j] = self.now
            self._requeued.add(j)
            self.queue.append(j)

    # ---- run segments ----------------------------------------------------
    def _start_segment(self, job: JobRuntime,
                       init_layer: Optional[Layer] = None):
        """``init_layer`` attributes this start's INIT time: scheduling
        for defrag/migration restarts; otherwise compiler for a cold
        compile and framework when the AOT cache skips it."""
        s = job.spec
        t = self.now
        q0 = self._queued_since.pop(s.job_id, None)
        if q0 is not None and t > q0:
            wait_phase = (Phase.PARTIAL if s.job_id in self._requeued
                          else Phase.QUEUED)
            self._emit(job, wait_phase, q0, t, layer=Layer.SCHEDULING)
        self._requeued.discard(s.job_id)
        if init_layer is None:
            init_layer = (Layer.FRAMEWORK if s.compile_cache_hit
                          else Layer.COMPILER)
        self._epoch[s.job_id] += 1
        epoch = self._epoch[s.job_id]
        gen = self._gen_of(s.job_id)
        assembly = 0.0
        if s.size_class == "xl":
            assembly = self.cfg.xl_assembly_per_pod * (s.chips // self.cfg.pod_size)
            t += assembly
        init = s.effective_init()
        t += init
        # elastic resize: restarting at a different width re-partitions the
        # checkpointed state — the measured transfer cost (bytes moved
        # between the old and new partition assignments over DCN)
        reshard = 0.0
        if job.last_chips and job.last_chips != s.chips:
            reshard = reshard_seconds(s.arch, job.last_chips, s.chips)
            t += reshard

        step_f, ckpt_f, stall_f = self._rates(s)
        # work rate in reference chip-seconds: slower generations do
        # proportionally less of the job's work per allocated second
        wall_needed = job.remaining / (s.chips * gen[1] * step_f)
        end = t + wall_needed

        # failure sampling over the allocated slice (scenario MTBF shocks
        # scale the base rate)
        rate = s.chips / (self.cfg.chip_mtbf * self._mtbf_factor)
        t_fail = t + self.rng.expovariate(rate) if rate > 0 else math.inf

        # assembly/INIT intervals are emitted at segment *close* (clipped
        # to the stop time), so a kill that lands mid-setup — preemption,
        # maintenance drain, failure burst — cannot leave phantom
        # allocated chip-time beyond the kill (or the horizon)
        seg = {"t_sched": self.now, "assembly": assembly, "init": init,
               "init_layer": init_layer, "reshard": reshard, "t_run0": t,
               "epoch": epoch, "step_f": step_f, "ckpt_f": ckpt_f,
               "stall_f": stall_f, "gen": gen}
        self.running[s.job_id] = seg
        job.started = self.now
        if t_fail < min(end, self.cfg.horizon):
            self._push(t_fail, "failure", f"{s.job_id}:{epoch}")
        elif end <= self.cfg.horizon:
            self._push(end, "complete", f"{s.job_id}:{epoch}")
        # else: runs past horizon; closed at the end of sim

    def _stop_segment(self, job: JobRuntime, lost: bool,
                      lost_layer: Layer = Layer.HARDWARE):
        """Close the running segment at self.now, crediting work.

        ``lost_layer`` attributes the rolled-back work: hardware for
        failures (independent and burst), scheduling for preemptions."""
        s = job.spec
        seg = self.running.pop(s.job_id, None)
        if seg is None:
            return
        t0 = seg["t_run0"]
        gen = seg["gen"]
        # setup phases, clipped to the actual stop time
        t_setup = seg["t_sched"]
        if seg["assembly"] > 0:
            self._emit(job, Phase.PARTIAL, t_setup,
                       min(self.now, t_setup + seg["assembly"]),
                       layer=Layer.SCHEDULING)
            t_setup += seg["assembly"]
        if seg["init"] > 0:
            self._emit(job, Phase.INIT, t_setup,
                       min(self.now, t_setup + seg["init"]),
                       layer=seg["init_layer"], gen=gen)
            t_setup += seg["init"]
        if seg["reshard"] > 0:
            # the resize transfer runs after program bring-up (the restore
            # read IS the re-partition), before productive steps
            self._emit(job, Phase.RESHARD, t_setup,
                       min(self.now, t_setup + seg["reshard"]),
                       layer=Layer.SCHEDULING, gen=gen)
        dur = max(0.0, self.now - t0)
        step_t = dur * seg["step_f"]
        ckpt_t = dur * seg["ckpt_f"]
        stall_t = dur * seg["stall_f"]
        work_rate = s.chips * gen[1]       # reference chip-s per step-second
        work = step_t * work_rate

        # checkpoint survival: work since last checkpoint boundary is lost
        # on failure/preemption (paper §4.3 RG definition)
        cycles = int(step_t // s.checkpoint_interval)
        survived = min(work, cycles * s.checkpoint_interval * work_rate)
        if lost:
            lost_work = work - survived
            credited = survived
        else:
            lost_work = 0.0
            credited = work

        t = t0
        good_t = credited / work_rate
        lost_t = lost_work / work_rate
        self._emit(job, Phase.STEP, t, t + good_t, layer=Layer.MODEL,
                   gen=gen)
        t += good_t
        if lost_t > 0:
            self._emit(job, Phase.LOST, t, t + lost_t, layer=lost_layer,
                       gen=gen)
            t += lost_t
        if ckpt_t > 0:
            self._emit(job, Phase.CHECKPOINT, t, t + ckpt_t,
                       layer=Layer.FRAMEWORK, gen=gen)
            t += ckpt_t
        if stall_t > 0:
            self._emit(job, Phase.DATA_STALL, t, t + stall_t,
                       layer=Layer.DATA, gen=gen)
        job.remaining = max(0.0, job.remaining - credited)
        job.checkpointed += credited
        job.last_chips = s.chips

    # ---- scenario events ---------------------------------------------------
    def _begin_maintenance(self, pod_id: int):
        """Scheduled maintenance: checkpoint-drain every occupant of the
        pod, then reserve it whole under a sentinel allocation until the
        window's ``maint_end``.  The lost capacity surfaces as SG loss
        (the denominator stays fleet-wide), and drained jobs' waits are
        PARTIAL — a scheduler-induced gap, not initial queueing.

        Overlapping windows on one pod take union semantics: a depth
        counter keeps the pod reserved until the last window ends."""
        self._maint_depth[pod_id] += 1
        if self._maint_depth[pod_id] > 1:      # already under maintenance
            return
        seen = set()
        for alloc_id in list(self.cluster.pod_jobs(pod_id)):
            if alloc_id.startswith(REPAIR_TAG):
                # the maintenance window subsumes the repair: the crew
                # fixes the slice while the pod is down (the pending
                # ``repair`` event then releases a missing tag, a no-op)
                self.cluster.release(alloc_id)
                continue
            job_id = owner_of(alloc_id)
            if job_id not in self.jobs or job_id in seen:
                continue   # another pod's sentinel / other gang slice
            seen.add(job_id)
            v = self.jobs[job_id]
            if job_id in self._gang_wait:      # mid-replacement-wait gang
                self._evict_gang_wait(job_id)
                continue
            self._stop_segment(v, lost=False)  # planned: checkpoint-resume
            self._release_job(job_id)
            if v.remaining > 0:
                self._queued_since[job_id] = self.now
                self._requeued.add(job_id)
                self.queue.append(job_id)
        self.cluster.reserve_pod(pod_id, f"{MAINT_TAG}{pod_id}")
        self._try_schedule()

    def _end_maintenance(self, pod_id: int):
        self._maint_depth[pod_id] -= 1
        if self._maint_depth[pod_id] > 0:      # a later window still holds
            return
        self.cluster.release(f"{MAINT_TAG}{pod_id}")
        self._try_schedule()

    def _failure_burst(self, idx: int):
        """Correlated failure shock (power/network domain event): every
        running job fails independently with the burst's kill fraction,
        on the scenario's dedicated rng stream."""
        burst = self.cfg.scenario.bursts[idx]
        for job_id in list(self.running):
            if self._burst_rng.random() >= burst.kill_frac:
                continue
            # slice-granularity kill: the burst takes one slice of a gang
            # (the victim draw stays on the scenario's dedicated stream)
            self._slice_failure(self.jobs[job_id], self._burst_rng)
        self._try_schedule()

    # ---- event loop -------------------------------------------------------
    def run(self):
        cfg = self.cfg
        sample_dt = (cfg.sample_dt if cfg.sample_dt is not None
                     else cfg.horizon / 200)
        next_sample = 0.0
        while self.events:
            t, _, kind, payload = heapq.heappop(self.events)
            if t > cfg.horizon:
                break
            while next_sample <= t:
                self._sample(next_sample)
                next_sample += sample_dt
            self.now = t
            if kind == "arrival":
                self._queued_since[payload] = t
                self.queue.append(payload)
                self._try_schedule()
            elif kind == "maint_start":
                self._begin_maintenance(int(payload))
            elif kind == "maint_end":
                self._end_maintenance(int(payload))
            elif kind == "burst":
                self._failure_burst(int(payload))
            elif kind == "control":
                if self.controller is not None:
                    self._on_control(t)
            elif kind == "repair":
                # failed hardware back in service (no-op when maintenance
                # already subsumed the sentinel)
                self.cluster.release(payload)
                self._try_schedule()
            elif kind in ("complete", "failure"):
                job_id, epoch = payload.rsplit(":", 1)
                job = self.jobs[job_id]
                if self._epoch[job_id] != int(epoch) \
                        or job_id not in self.running:
                    continue   # stale event from a preempted segment
                if kind == "complete":
                    self._stop_segment(job, lost=False)
                    self._release_job(job_id)
                else:
                    # MTBF failure: slice-granularity (the victim-slice
                    # draw rides the base failure stream)
                    self._slice_failure(job, self.rng)
                self._try_schedule()
        # close still-running segments at the horizon
        self.now = cfg.horizon
        for job_id in list(self.running):
            self._stop_segment(self.jobs[job_id], lost=False)
            self._release_job(job_id)
        # rigid gangs still holding survivors book the stall to the end
        for job_id in list(self._gang_wait):
            w = self._gang_wait.pop(job_id)
            job = self.jobs[job_id]
            s = job.spec
            self._emit(job, Phase.IDLE, w["t0"], cfg.horizon,
                       layer=Layer.HARDWARE, chips=s.chips - s.slice_chips)
            self._release_job(job_id)
        return self

    def _sample(self, t: float):
        occupied = sum(self.jobs[j].spec.chips for j in self.running)
        # rigid gangs waiting on a replacement slice still hold survivors
        occupied += sum(
            self.jobs[j].spec.chips - self.jobs[j].spec.slice_chips
            for j in self._gang_wait)
        self.telemetry.append({
            "t": t,
            "occupied": occupied,
            "free": self.cluster.free_chips(),
            "queued": len(self.queue),
            "fragmentation": self.cluster.fragmentation(),
        })

    # ---- reporting ---------------------------------------------------------
    @property
    def capacity_chip_time(self) -> float:
        return self.cluster.total_chips * self.cfg.horizon

    def pg_by_job(self) -> Dict[str, float]:
        return {j: r.spec.pg for j, r in self.jobs.items()}

    def report(self):
        """Streaming MPG report — no interval list required.  When the
        ledger is shared across clusters the denominator is fleet-wide;
        pass an explicit capacity to ``ledger.report`` for a local view."""
        return self.ledger.report()
