"""Vectorized fleet-sim engine: same decisions, batched accounting.

``VectorizedFleetSim`` is the ``SimConfig(engine="vectorized")`` engine
behind ``FleetSim``'s constructor dispatch.  The equivalence gate (golden
traces byte-identical, ``ledger.totals()`` bit-for-bit — see
``tests/test_vectorized.py``) forbids changing *what* the simulator does:
every scheduling decision, every rng draw, and every float operation must
happen in the same order as the reference engine.  So the speed comes
from four strictly behaviour-preserving moves:

  * **columnar interval emission** — ``_emit`` appends to struct-of-array
    buffers (one interned segment dict per distinct segment shape) and
    flushes thousands of rows at a time through
    ``GoodputLedger.add_intervals``, whose accumulators receive the same
    addends in the same order as per-event ``record`` calls;
  * **cached cluster geometry** — ``_CachedPod`` keeps ``largest_slice``
    / ``free_chips`` as O(1) reads (recomputed only on alloc/release) and
    ``_IndexedCluster`` keeps per-pod occupancy counts, killing the
    O(#allocations) ``pod_jobs`` scans inside the best-fit sort key;
  * **memoized failed scheduling attempts** — within one cluster state
    (tracked by a mutation version counter), a failed sub-pod allocation
    for ``want`` chips proves every allocation of ``want' >= want`` chips
    fails too (candidate pods are filtered by ``largest_slice >= want``,
    monotone in ``want``); a failed whole-pod allocation for ``need``
    pods proves the same for ``need' >= need``; and a declined preemption
    at ``(chips, eff)`` proves every request with ``chips' >= chips`` and
    ``eff' <= eff`` is declined (the victim-candidate set only shrinks as
    ``eff`` drops, and the freed-chips requirement only grows) — so a
    long stuck queue costs O(1) per job instead of a cluster scan each;
  * **a small-job index** — ``_small_running`` mirrors the running set
    restricted to "small" jobs in insertion order, making the defrag
    policy's ``_smallest_running`` victim pick O(#small) instead of a
    full running-set scan with per-job ``size_class`` recomputation.

The memos are *failure-only*: a hit can only skip work that provably
returns ``None``; every success (which mutates the cluster) runs the real
policy code and bumps the version, invalidating all memos.  Monotonicity
only holds for the built-in policies, so the memo paths are gated on
exact policy types and fall back to the reference flow otherwise.

Randomness is untouched: the same per-component ``random.Random`` streams
draw in the same order (one ``expovariate`` per segment start), which is
what keeps the golden traces byte-identical.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.goodput import Layer, Phase
from repro.fleet.cluster import (Allocation, Cluster, _BuddyPod,
                                 _round_pow2)
from repro.fleet.job import JobRuntime, JobSpec
from repro.fleet.policies import (BestFitPlacement, FirstFitPlacement,
                                  NoPreemption, PlacementPolicy,
                                  PriorityOnlyPreemption,
                                  ProtectXLPreemption, SpreadPlacement)
from repro.fleet.sim import FleetSim, SimConfig

_FLUSH_EVERY = 8192          # buffered interval rows per ledger flush
_NO_FAIL = 1 << 62           # "no failed size recorded yet" sentinel
_POW2: Dict[int, int] = {}   # memoized _round_pow2 (few distinct sizes)

# memo soundness is proved against the shipped policies only; custom
# strategy objects (even subclasses — they may override the decision
# methods) take the reference slow path
_MEMO_PLACEMENTS = (BestFitPlacement, FirstFitPlacement, SpreadPlacement)
_MEMO_PREEMPTIONS = (ProtectXLPreemption, PriorityOnlyPreemption)


class _CachedPod(_BuddyPod):
    """Buddy pod with O(1) ``largest_slice`` / ``free_chips`` reads.

    ``free_chips`` is maintained incrementally (an allocation removes
    exactly its rounded block, a release restores it; buddy splits and
    coalesces conserve the total).  ``largest_slice`` is recomputed
    lazily on first read after a mutation — the best-fit scan and the
    defrag drain target query it millions of times per simulated month,
    but a pod mutates far less often than it is read."""

    def __init__(self, pod_id: int, size: int):
        super().__init__(pod_id, size)
        self._largest = size
        self._free = size
        self._dirty = False

    def largest_slice(self) -> int:
        if self._dirty:
            self._largest = _BuddyPod.largest_slice(self)
            self._dirty = False
        return self._largest

    def free_chips(self) -> int:
        return self._free

    def alloc(self, chips: int) -> Optional[int]:
        off = super().alloc(chips)
        if off is not None:
            self._free -= 1 << self.used[off]
            self._dirty = True
        return off

    def release(self, offset: int) -> None:
        order = self.used[offset]
        super().release(offset)
        self._free += 1 << order
        self._dirty = True


class _IndexedCluster(Cluster):
    """Cluster with cached pods, per-pod occupancy counts, and a mutation
    version counter (the scheduling-memo invalidation signal).

    ``pod_occupancy(pid)`` equals ``len(cluster.pod_jobs(pid))`` at all
    times — sub-pod allocations, whole-pod (XL) members, and maintenance
    sentinels all count one each, exactly like ``pod_jobs``."""

    def __init__(self, n_pods: int = 8, pod_size: int = 256):
        super().__init__(n_pods, pod_size)
        self.pods = [_CachedPod(i, pod_size) for i in range(n_pods)]
        self.version = 0
        self._occ = [0] * n_pods
        # maintenance sentinels are the only allocations without a backing
        # job, so this set equals the defrag policy's "reserved" pod scan
        self.reserved_pods: set = set()
        self._reserved_tags: Dict[str, int] = {}

    def pod_occupancy(self, pod_id: int) -> int:
        return self._occ[pod_id]

    def alloc(self, job_id: str, chips: int, prefer_tight: bool = True,
              exclude: Tuple[int, ...] = (),
              pod_key=None) -> Optional[Allocation]:
        a = super().alloc(job_id, chips, prefer_tight=prefer_tight,
                          exclude=exclude, pod_key=pod_key)
        if a is not None:
            self.version += 1
            if a.pod >= 0:
                self._occ[a.pod] += 1
            else:
                for pid in a.pods:
                    self._occ[pid] += 1
        return a

    def release(self, job_id: str) -> None:
        a = self.allocations.get(job_id)
        if a is None:
            return
        super().release(job_id)
        self.version += 1
        if a.pod >= 0:
            self._occ[a.pod] -= 1
        else:
            for pid in a.pods:
                self._occ[pid] -= 1
        pid = self._reserved_tags.pop(job_id, None)
        if pid is not None:
            self.reserved_pods.discard(pid)

    def reserve_pod(self, pod_id: int, tag: str) -> None:
        super().reserve_pod(pod_id, tag)
        self.version += 1
        self._occ[pod_id] += 1
        self.reserved_pods.add(pod_id)
        self._reserved_tags[tag] = pod_id


class _FastBestFit(BestFitPlacement):
    """Best-fit with the candidate scan inlined against the indexed
    cluster: one pass keeping the first pod minimizing
    ``(largest_slice, -occupancy)`` — the same pod a stable sort of the
    filtered candidate list would put first — without building the list,
    the lambda key, or the sort.  Sub-pod bookkeeping mirrors
    ``_IndexedCluster.alloc`` exactly; whole-pod (XL) requests fall back
    to the generic path, which ignores placement ordering anyway."""

    def alloc(self, cluster, job_id: str, chips: int,
              exclude: Tuple[int, ...] = ()):
        if chips > cluster.pod_size:
            return cluster.alloc(job_id, chips, exclude=exclude,
                                 pod_key=self.pod_key(cluster))
        want = _POW2.get(chips)
        if want is None:
            want = _POW2[chips] = _round_pow2(chips)
        occ = cluster._occ
        best = None
        bl = bo = 0
        for p in cluster.pods:
            # inlined _CachedPod.largest_slice (the scan reads every pod
            # on every allocation; most pods are clean most of the time)
            ls = p.largest_slice() if p._dirty else p._largest
            if ls < want or (exclude and p.pod_id in exclude):
                continue
            o = occ[p.pod_id]
            if best is None or ls < bl or (ls == bl and o > bo):
                best, bl, bo = p, ls, o
        if best is None:
            return None
        off = best.alloc(want)
        a = Allocation(job_id, best.pod_id, off, want)
        cluster.allocations[job_id] = a
        cluster.version += 1
        occ[best.pod_id] += 1
        return a


class VectorizedFleetSim(FleetSim):
    """Decision-identical fast engine (see module docstring)."""

    def __init__(self, cfg: SimConfig,
                 ledger=None, keep_intervals: Optional[bool] = None):
        # engine state must exist before super().__init__ runs the
        # _make_cluster hook and scenario setup
        self._bj: List[str] = []         # columnar emit buffers
        self._bp: List[Phase] = []
        self._b0: List[float] = []
        self._b1: List[float] = []
        self._bc: List[int] = []
        self._bg: List[float] = []
        self._bs: List[Dict[str, str]] = []
        self._seg_intern: Dict[tuple, Dict[str, str]] = {}
        # chips -> {job_id: None} buckets over running "small" jobs, each
        # bucket in running-dict insertion order (<= 8 distinct chip
        # counts, so the defrag victim pick scans buckets, not jobs)
        self._small_running: Dict[int, Dict[str, None]] = {}
        self._memo_version = -1
        self._memo_drain: Optional[tuple] = None
        self._fail_min0 = _NO_FAIL       # failed sub-pod want, exclude=()
        self._fail_min_dr = _NO_FAIL     # failed sub-pod want, exclude=drain
        self._fail_need = _NO_FAIL       # failed whole-pod need
        self._pre_fail_sub: List[Tuple[int, float]] = []
        self._pre_fail_xl: List[Tuple[int, float]] = []
        self._cand_epoch = 0             # preempt-candidate-set generation
        self._pre_sub_epoch = -1
        super().__init__(cfg, ledger, keep_intervals)
        if type(self.placement) is BestFitPlacement:
            self.placement = _FastBestFit()
        self._memo_placement = isinstance(
            self.placement, _MEMO_PLACEMENTS) and type(
            self.placement) in (_MEMO_PLACEMENTS + (_FastBestFit,))

    def _make_cluster(self, cfg: SimConfig) -> Cluster:
        return _IndexedCluster(cfg.n_pods, cfg.pod_size)

    # ---- columnar interval emission --------------------------------------
    def _emit(self, job: JobRuntime, phase: Phase, t0: float, t1: float,
              layer: Layer, gen: Optional[Tuple[str, float]] = None,
              chips: Optional[int] = None):
        if t1 <= t0:
            return
        s = job.spec
        # per-spec memo: (layer, gen) -> (interned segment dict, pg).
        # every field feeding seg/pg is immutable on a JobSpec instance,
        # and specs are only replaced wholesale (fresh instance, no memo)
        ec = s.__dict__.get("_emit_c")
        if ec is None:
            ec = s.__dict__["_emit_c"] = {}
        ent = ec.get((layer, gen))
        if ent is not None:
            seg, pg = ent
        else:
            key = (s.size_class, s.phase_kind, s.arch, s.framework,
                   s.async_checkpoint, layer.value,
                   None if gen is None else gen[0])
            seg = self._seg_intern.get(key)
            if seg is None:
                seg = {
                    "size_class": s.size_class, "phase_kind": s.phase_kind,
                    "arch": s.arch, "framework": s.framework,
                    "ckpt": "async" if s.async_checkpoint else "sync",
                    "emitter": "fleet", "layer": layer.value,
                }
                if gen is not None:
                    seg["generation"] = gen[0]
                self._seg_intern[key] = seg
            pg = s.pg
            if gen is not None:
                pg = s.pg * gen[1]
            ec[(layer, gen)] = (seg, pg)
        self._bj.append(s.job_id)
        self._bp.append(phase)
        self._b0.append(t0)
        self._b1.append(t1)
        self._bc.append(s.chips if chips is None else chips)
        self._bg.append(pg)
        self._bs.append(seg)
        if len(self._b0) >= _FLUSH_EVERY:
            self._flush()

    def _flush(self) -> None:
        if not self._b0:
            return
        self.ledger.add_intervals(self._bj, self._bp, self._b0, self._b1,
                                  self._bc, self._bg, self._bs)
        self._bj = []
        self._bp = []
        self._b0 = []
        self._b1 = []
        self._bc = []
        self._bg = []
        self._bs = []

    @property
    def intervals(self):
        self._flush()
        return FleetSim.intervals.fget(self)

    def report(self):
        self._flush()
        return super().report()

    def run(self):
        super().run()
        self._flush()
        return self

    def _control_sync(self) -> None:
        # a controller observation must see the same ledger/waterfall
        # state the reference engine would at this boundary
        self._flush()

    # ---- live policy switching -------------------------------------------
    def set_policies(self, placement=None, preemption=None,
                     defrag=None) -> None:
        super().set_policies(placement, preemption, defrag)
        # re-derive the policy-dependent fast paths, exactly as __init__
        # does, and drop every scheduling memo: facts proved against the
        # old policy objects are no longer sound (clearing memos only
        # re-runs real policy code with identical results, so the switch
        # stays decision-identical to the reference engine)
        if type(self.placement) is BestFitPlacement:
            self.placement = _FastBestFit()
        self._memo_placement = isinstance(
            self.placement, _MEMO_PLACEMENTS) and type(
            self.placement) in (_MEMO_PLACEMENTS + (_FastBestFit,))
        self._memo_version = -1
        self._memo_drain = None
        self._fail_min0 = _NO_FAIL
        self._fail_min_dr = _NO_FAIL
        self._fail_need = _NO_FAIL
        self._pre_fail_sub = []
        self._pre_fail_xl = []
        self._cand_epoch += 1
        self._pre_sub_epoch = -1

    # ---- cached productive-rate model ------------------------------------
    def _rates(self, s: JobSpec) -> Tuple[float, float, float]:
        cached = s.__dict__.get("_rates_c")
        pause = self.cfg.async_snapshot_pause
        if cached is not None and cached[0] == pause:
            return cached[1]
        r = super()._rates(s)
        s.__dict__["_rates_c"] = (pause, r)
        return r

    # ---- small-job victim index ------------------------------------------
    def _start_segment(self, job: JobRuntime,
                       init_layer: Optional[Layer] = None):
        super()._start_segment(job, init_layer)
        s = job.spec
        if s.size_class == "small":
            bucket = self._small_running.get(s.chips)
            if bucket is None:
                bucket = self._small_running[s.chips] = {}
            bucket[s.job_id] = None
        if init_layer is not Layer.SCHEDULING:
            # a defrag/drain migration (the only SCHEDULING-layer start)
            # stop+restarts the same job with the same priority / chips /
            # size_class / preemption count — candidacy-neutral for the
            # preemption memo.  Every other start can grow the victim set.
            self._cand_epoch += 1

    def _stop_segment(self, job: JobRuntime, lost: bool,
                      lost_layer: Layer = Layer.HARDWARE):
        super()._stop_segment(job, lost, lost_layer)
        s = job.spec
        bucket = self._small_running.get(s.chips)
        if bucket is not None:
            bucket.pop(s.job_id, None)

    # ---- memoized scheduling pass ----------------------------------------
    def _sync_memo(self) -> None:
        v = self.cluster.version
        if v != self._memo_version:
            self._memo_version = v
            self._fail_min0 = _NO_FAIL
            self._fail_min_dr = _NO_FAIL
            self._fail_need = _NO_FAIL
            # _pre_fail_xl scans cluster.pod_jobs -> version-keyed;
            # _pre_fail_sub never reads the cluster -> epoch-keyed below
            self._pre_fail_xl = []

    def _fast_alloc(self, job_id: str, chips: int,
                    exclude: Tuple[int, ...]) -> Optional[Allocation]:
        """``placement.alloc`` with failure memoization (sound for the
        built-in placement policies: they order candidates but never
        decline a feasible one, so failure is a pure cluster-state fact,
        monotone in the rounded request size)."""
        if not self._memo_placement:
            return self.placement.alloc(self.cluster, job_id, chips,
                                        exclude=exclude)
        if self.cluster.version != self._memo_version:
            self._sync_memo()
        if chips <= self.cfg.pod_size:
            want = _POW2.get(chips)
            if want is None:
                want = _POW2[chips] = _round_pow2(chips)
            if want >= (self._fail_min_dr if exclude else self._fail_min0):
                return None
            a = self.placement.alloc(self.cluster, job_id, chips,
                                     exclude=exclude)
            if a is None:
                if exclude:
                    if want < self._fail_min_dr:
                        self._fail_min_dr = want
                else:
                    # failing with no exclusions implies failing with any
                    if want < self._fail_min0:
                        self._fail_min0 = want
                    if want < self._fail_min_dr:
                        self._fail_min_dr = want
            return a
        need = -(-chips // self.cfg.pod_size)
        if need >= self._fail_need:
            return None
        a = self.placement.alloc(self.cluster, job_id, chips,
                                 exclude=exclude)
        if a is None and need < self._fail_need:
            self._fail_need = need
        return a

    def _place(self, alloc_id: str, chips: int, exclude: tuple = ()):
        # every slice placement (gang or single) rides the failure memo
        return self._fast_alloc(alloc_id, chips, exclude)

    def _preempt_for(self, job: JobRuntime) -> bool:
        pre = self.preemption
        tp = type(pre)
        if tp is NoPreemption:
            return False                  # victims_for is constant None
        if tp not in _MEMO_PREEMPTIONS:
            return super()._preempt_for(job)
        chips = job.spec.chips
        eff = self._eff_priority(job.spec.job_id)
        if chips > self.cfg.pod_size:
            if self.cluster.version != self._memo_version:
                self._sync_memo()
            fails = self._pre_fail_xl
        else:
            if self._pre_sub_epoch != self._cand_epoch:
                self._pre_sub_epoch = self._cand_epoch
                self._pre_fail_sub = []
            fails = self._pre_fail_sub
        for c, e in fails:
            if chips >= c and eff <= e:
                return False              # monotone failure propagation
        victims = pre.victims_for(self, job)
        if not victims:
            fails.append((chips, eff))
            return False
        self._evict_victims(victims)
        return True

    def _try_schedule(self):
        # identical control flow to FleetSim._try_schedule; the sort key
        # inlines _eff_priority with the exact same float operations
        jobs = self.jobs
        qs = self._queued_since
        req = self._requeued
        now = self.now
        aging = self.cfg.aging_hours * 3600.0
        self.queue.sort(key=lambda j: (
            -((jobs[j].spec.priority + 1.0 if j in req
               else jobs[j].spec.priority)
              + (now - qs.get(j, now)) / aging),
            jobs[j].spec.arrival))
        drain = self._drain_for_xl()
        if self.cluster.version != self._memo_version:
            self._sync_memo()
        if drain != self._memo_drain:
            # the drain-exclusion memo is only valid against one drain set
            self._memo_drain = drain
            self._fail_min_dr = _NO_FAIL
        self._refill_gangs(drain)
        self._regrow_elastic(drain)
        scheduled = []
        for job_id in list(self.queue):
            if self._sched_one(jobs[job_id], drain):
                scheduled.append(job_id)
        if scheduled:
            # remove each scheduled id's first occurrence in one pass
            # (reference does repeated queue.remove — same result)
            first = set(scheduled)
            kept = []
            for j in self.queue:
                if j in first:
                    first.discard(j)
                else:
                    kept.append(j)
            self.queue[:] = kept
