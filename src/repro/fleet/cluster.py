"""Fleet hardware model: pods of chips with topology-constrained slices.

Each pod is a buddy allocator over power-of-two slices (1..pod_size chips):
an ML job needs a *contiguous torus slice*, not merely free chips, so a pod
with 128 free-but-fragmented chips can still reject a 128-chip request —
this is precisely the Capacity != Availability myth of paper §4.1 (Myth 1),
and the buddy structure is the standard abstraction of TPU slice shapes
(1x1, 2x2, 4x4, ... sub-tori).

Multi-pod ("extra-large") jobs take whole pods connected over DCN.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


SLICE_SEP = "#s"     # gang-slice allocation ids: "<job_id>#s<k>"

# sentinel prefix: a failed slice's chips held out of service until its
# repair window elapses (SimConfig.slice_repair_s); unlike maintenance
# sentinels these are sub-pod and do NOT mark their pod reserved
REPAIR_TAG = "__repair__"


def owner_of(alloc_id: str) -> str:
    """Owning job of an allocation id: gang slices allocate per-slice
    under ``"<job_id>#s<k>"``; every other allocation is its own owner."""
    i = alloc_id.find(SLICE_SEP)
    return alloc_id[:i] if i >= 0 else alloc_id


@dataclasses.dataclass
class Allocation:
    job_id: str
    pod: int              # -1 for multi-pod
    offset: int           # buddy offset within pod (chips)
    chips: int
    pods: Tuple[int, ...] = ()   # for multi-pod allocations


class _BuddyPod:
    """Buddy allocator over one pod's chips."""

    def __init__(self, pod_id: int, size: int):
        assert _is_pow2(size)
        self.pod_id = pod_id
        self.size = size
        # free lists: order -> sorted list of offsets; order k = 2^k chips
        self.max_order = size.bit_length() - 1
        self.free: Dict[int, List[int]] = {k: [] for k in range(self.max_order + 1)}
        self.free[self.max_order] = [0]
        self.used: Dict[int, int] = {}   # offset -> order

    def free_chips(self) -> int:
        return sum(len(v) * (1 << k) for k, v in self.free.items())

    def largest_slice(self) -> int:
        for k in range(self.max_order, -1, -1):
            if self.free[k]:
                return 1 << k
        return 0

    def alloc(self, chips: int) -> Optional[int]:
        order = max(chips.bit_length() - 1, 0)
        if (1 << order) < chips:
            order += 1
        k = order
        while k <= self.max_order and not self.free[k]:
            k += 1
        if k > self.max_order:
            return None
        # split down
        while k > order:
            off = self.free[k].pop(0)
            k -= 1
            self.free[k].extend([off, off + (1 << k)])
            self.free[k].sort()
        off = self.free[order].pop(0)
        self.used[off] = order
        return off

    def release(self, offset: int):
        order = self.used.pop(offset)
        # coalesce buddies
        while order < self.max_order:
            buddy = offset ^ (1 << order)
            if buddy in self.free[order]:
                self.free[order].remove(buddy)
                offset = min(offset, buddy)
                order += 1
            else:
                break
        self.free[order].append(offset)
        self.free[order].sort()

    def fragmentation(self) -> float:
        """1 - largest_slice / free_chips (0 = perfectly defragmented)."""
        f = self.free_chips()
        return 1.0 - self.largest_slice() / f if f else 0.0


class Cluster:
    def __init__(self, n_pods: int = 8, pod_size: int = 256):
        self.n_pods = n_pods
        self.pod_size = pod_size
        self.pods = [_BuddyPod(i, pod_size) for i in range(n_pods)]
        self.allocations: Dict[str, Allocation] = {}

    @property
    def total_chips(self) -> int:
        return self.n_pods * self.pod_size

    def free_chips(self) -> int:
        return sum(p.free_chips() for p in self.pods)

    def can_fit(self, chips: int) -> bool:
        if chips <= self.pod_size:
            return any(p.largest_slice() >= _round_pow2(chips)
                       for p in self.pods)
        need = -(-chips // self.pod_size)
        return sum(1 for p in self.pods
                   if p.largest_slice() == self.pod_size) >= need

    def alloc(self, job_id: str, chips: int, prefer_tight: bool = True,
              exclude: Tuple[int, ...] = (),
              pod_key=None) -> Optional[Allocation]:
        """Topology-aware placement.  ``pod_key`` (a sort key over pods,
        normally supplied by a ``fleet.policies.PlacementPolicy``) orders
        the candidate pods; the default reproduces best-fit — tightest pod
        first (defragmentation-friendly, paper §5.3).  ``exclude`` pods are
        draining for a queued multi-pod job and take no new sub-pod work."""
        if chips <= self.pod_size:
            want = _round_pow2(chips)
            candidates = [p for p in self.pods
                          if p.largest_slice() >= want
                          and p.pod_id not in exclude]
            if not candidates:
                return None
            if pod_key is not None:
                candidates.sort(key=pod_key)
            elif prefer_tight:
                candidates.sort(key=lambda p: (p.largest_slice(),
                                               -len(self.pod_jobs(p.pod_id))))
            pod = candidates[0]
            off = pod.alloc(want)
            alloc = Allocation(job_id, pod.pod_id, off, want)
        else:
            need = -(-chips // self.pod_size)
            empties = [p for p in self.pods
                       if p.largest_slice() == self.pod_size]
            if len(empties) < need:
                return None
            pods = []
            for p in empties[:need]:
                p.alloc(self.pod_size)
                pods.append(p.pod_id)
            alloc = Allocation(job_id, -1, 0, need * self.pod_size,
                               tuple(pods))
        self.allocations[job_id] = alloc
        return alloc

    def release(self, job_id: str):
        alloc = self.allocations.pop(job_id, None)
        if alloc is None:
            return
        if alloc.pod >= 0:
            self.pods[alloc.pod].release(alloc.offset)
        else:
            for pid in alloc.pods:
                self.pods[pid].release(0)

    def retag(self, old_id: str, new_id: str) -> None:
        """Transfer an allocation to a sentinel owner (a failed slice held
        out of service for repair) without touching the free lists — the
        chips stay occupied, only the owning id changes."""
        a = self.allocations.pop(old_id)
        self.allocations[new_id] = dataclasses.replace(a, job_id=new_id)

    def reserve_pod(self, pod_id: int, tag: str) -> None:
        """Take a whole (empty) pod out of service under a sentinel
        allocation — a scheduled-maintenance drain.  The pod must be fully
        free (the sim drains its occupants first); ``release(tag)`` returns
        it to service."""
        off = self.pods[pod_id].alloc(self.pod_size)
        if off is None:
            raise RuntimeError(f"pod {pod_id} not drained; cannot reserve")
        self.allocations[tag] = Allocation(tag, pod_id, off, self.pod_size)

    def pod_jobs(self, pod_id: int) -> List[str]:
        return [j for j, a in self.allocations.items()
                if a.pod == pod_id or pod_id in a.pods]

    def fragmentation(self) -> float:
        f = [p.fragmentation() for p in self.pods if p.free_chips()]
        return sum(f) / len(f) if f else 0.0


def _round_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length() if n > 1 else 1
