"""Job model: what the fleet runs.

Jobs reference the assigned architectures — their Program Goodput comes
from the dry-run roofline table, closing the loop between the compiled
artifacts and the fleet metric (paper Fig. 10's per-workload breakdown).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

SIZE_CLASSES = ("small", "medium", "large", "xl")


def size_class(chips: int, pod_size: int = 256) -> str:
    if chips <= 8:
        return "small"
    if chips <= 64:
        return "medium"
    if chips <= pod_size:
        return "large"
    return "xl"


@dataclasses.dataclass
class JobSpec:
    job_id: str
    chips: int
    # productive work still to do, in chip-seconds
    work: float
    phase_kind: str = "train"          # train | serve | bulk_inference
    arch: str = "smollm-135m"
    priority: int = 1                  # higher preempts lower
    framework: str = "jax-pathways"    # jax-pathways | multi-client
    checkpoint_interval: float = 600.0     # seconds between checkpoints
    checkpoint_write: float = 30.0         # sync write cost (seconds)
    async_checkpoint: bool = False         # paper §5.2 optimization
    compile_cache_hit: bool = False        # AOT cache (paper §5.2)
    init_time: float = 120.0               # cold program setup + compile
    data_stall_frac: float = 0.03          # input-pipeline stall fraction
    pg: float = 0.45                       # Program Goodput of its program
    elastic: bool = False
    n_slices: int = 1                      # gang width: independent slices
    arrival: float = 0.0

    def __post_init__(self):
        # a zero-chip or negative-work spec silently corrupts ledger
        # totals (chip_time factors `chips`; remaining starts at `work`)
        if self.chips < 1:
            raise ValueError(f"{self.job_id}: chips must be >= 1, "
                             f"got {self.chips}")
        if self.work <= 0:
            raise ValueError(f"{self.job_id}: work must be > 0, "
                             f"got {self.work}")
        if self.checkpoint_interval <= 0:
            raise ValueError(f"{self.job_id}: checkpoint_interval must be "
                             f"> 0, got {self.checkpoint_interval}")
        if self.n_slices < 1:
            raise ValueError(f"{self.job_id}: n_slices must be >= 1, "
                             f"got {self.n_slices}")
        if self.chips % self.n_slices:
            raise ValueError(f"{self.job_id}: chips ({self.chips}) must "
                             f"divide evenly into n_slices "
                             f"({self.n_slices}) equal slices")

    @property
    def slice_chips(self) -> int:
        """Chips per gang slice (== chips for single-slice jobs)."""
        return self.chips // self.n_slices

    @property
    def size_class(self) -> str:
        # memoized per instance: specs only change via dataclasses.replace
        # (a fresh instance), and this sits on the scheduler's hot path
        sc = self.__dict__.get("_size_class")
        if sc is None:
            sc = self.__dict__["_size_class"] = size_class(self.chips)
        return sc

    def effective_init(self) -> float:
        init = self.init_time
        if self.compile_cache_hit:
            init *= 0.35               # AOT cache skips JIT compile
        if self.framework == "multi-client":
            init *= 1.6                # per-host connect/compile fan-out
        return init

    def effective_stall(self) -> float:
        stall = self.data_stall_frac
        if self.framework == "multi-client":
            stall *= 1.5
        if self.phase_kind == "bulk_inference":
            stall *= 2.0               # sharded weight reads (paper Fig 15)
        if self.phase_kind == "serve":
            stall += 0.10              # demand-trough idle (paper Fig 15)
        return stall


@dataclasses.dataclass
class JobRuntime:
    """Mutable scheduler-side state of a job."""
    spec: JobSpec
    remaining: float = 0.0             # chip-seconds of work left
    checkpointed: float = 0.0          # chip-seconds safely persisted
    since_checkpoint: float = 0.0      # productive chip-s since last ckpt
    started: Optional[float] = None    # current allocation start
    preemptions: int = 0
    failures: int = 0
    target_chips: int = 0              # submitted width (regrow target)
    target_slices: int = 0             # submitted gang width
    last_chips: int = 0                # width of the last run segment
                                       # (0 until first scheduled; a width
                                       # change on restart pays a reshard)

    def __post_init__(self):
        if self.remaining == 0.0:
            self.remaining = self.spec.work
        if self.target_chips == 0:
            self.target_chips = self.spec.chips
        if self.target_slices == 0:
            self.target_slices = self.spec.n_slices
