"""Online adaptive MPG controller (paper §5–§6, closed-loop).

The paper's central claim is that MPG is an *optimization* signal, not a
report: the per-layer waterfall tells a fleet operator which knob to turn
while the fleet is running.  The offline advisor (``repro.fleet.advisor``)
ranks knobs after the fact by full resimulation; this module closes the
ROADMAP's loop with an :class:`AdaptiveController` that reacts *during*
the run:

  * it subscribes to the ledger's windowed SG/RG/PG series
    (:meth:`~repro.core.ledger.GoodputLedger.tail_series`) and to a
    streaming :class:`~repro.core.attribution.AttributionWaterfall` of its
    own (attached before the first event, like a trace recorder);
  * at decision boundaries — every ``windows_per_decision`` ledger windows
    — it reduces the observation deltas to a :class:`Signals` row and asks
    its rule table for an :class:`Action`;
  * accepted actions switch the live sim's placement/preemption/defrag
    policy objects (:meth:`FleetSim.set_policies`), toggle the fleet-wide
    elastic-resize override, and retune every pending job's Daly
    checkpoint interval from the *observed* failure rate;
  * hysteresis (distinct enter/exit thresholds + a consecutive-calm exit
    count) and a hard cooldown prevent thrashing: at most one switch per
    ``cooldown_s``, enforced structurally in :meth:`_consider`;
  * every accepted switch emits a ``Phase.CONTROL`` scheduling-layer
    interval, so the cost of control is itself a visible waterfall bucket
    (``policy_switch``).

Determinism contract: a decision consumes only state that is bit-for-bit
identical across engines — integer counters (failures, queue and gang
membership), the waterfall's exact cells, and the ledger's windowed
accumulators — and the vectorized engine flushes its columnar buffers
before every observation (``FleetSim._control_sync``), so a controlled
run produces identical ``ledger.totals()`` on both engines.

The rule table is the deliverable, but the hook is policy-shaped: any
object with ``propose(signals, mode) -> Optional[Action]`` (a learned
policy, a bandit, a schedule) drops into ``AdaptiveController(rules=...)``
unchanged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.attribution import AttributionWaterfall
from repro.core.goodput import Layer, Phase
from repro.fleet.policies import PAPER_COMBO

CONTROL_JOB_ID = "__controller__"


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Decision cadence, hysteresis thresholds, and switch costs."""
    windows_per_decision: int = 1     # decision boundary every K windows
    cooldown_s: float = 2 * 3600.0    # hard floor between accepted switches
    # survival-mode entry (any one suffices; see RuleTable.propose).  The
    # failure trigger is scale-aware: a boundary is a storm when its
    # failure count reaches ``storm_rate_x`` times the fleet's *nominal*
    # expectation (chips * period / chip_mtbf), floored at
    # ``storm_failures`` so a tiny fleet's nominal-0.004 expectation
    # doesn't make every single failure a storm
    storm_failures: int = 2           # absolute floor, failures per period
    storm_rate_x: float = 3.0         # x nominal expected failures/period
    storm_rollback_frac: float = 0.20   # rollback+stall loss / period cap
    # survival-mode exit hysteresis: a boundary only counts as calm below
    # the (much lower) off-threshold, `calm_boundaries` consecutive calm
    # boundaries are required before restoring baseline, and the exit is
    # vetoed outright while the *cumulative* observed failure rate stays
    # above ``calm_rate_x`` times nominal — a fleet whose MTBF is
    # genuinely degraded (an adversarial mtbf_factor shock) never looks
    # calm, no matter how quiet one night is
    calm_rollback_frac: float = 0.01
    calm_boundaries: int = 2
    calm_rate_x: float = 1.5
    # scheduler-rescue rule: sustained queue overhang under non-paper
    # policies switches the live policy objects to the paper combo
    rescue_queue_frac: float = 0.50   # queued chip demand / fleet chips
    rescue_boundaries: int = 2
    # accounting cost of one switch (the Phase.CONTROL interval)
    switch_cost_s: float = 120.0
    switch_chips: int = 1
    # Daly retune: observed-failure evidence floor before trusting the
    # empirical MTBF estimate
    min_failures_for_retune: int = 2
    # correlated-burst detector (stricter than the storm trigger): a
    # boundary whose failure count is this far above nominal is a
    # mass-kill event, not background hazard — its failures are excluded
    # from the background-MTBF evidence, and once one has been seen the
    # retune stops lengthening intervals (Daly's exponential model says
    # nothing about the next correlated kill).  A Poisson pair on a calm
    # fleet can reach the storm floor but not this one
    burst_failures: int = 3           # absolute floor, failures per period
    burst_rate_x: float = 10.0        # x nominal expected failures/period

    def __post_init__(self):
        if self.windows_per_decision < 1:
            raise ValueError(f"windows_per_decision must be >= 1, "
                             f"got {self.windows_per_decision}")
        if self.cooldown_s < 0:
            raise ValueError(f"cooldown_s must be >= 0, "
                             f"got {self.cooldown_s}")
        if not self.calm_rollback_frac < self.storm_rollback_frac:
            raise ValueError(
                "hysteresis needs calm_rollback_frac < storm_rollback_frac, "
                f"got {self.calm_rollback_frac} vs {self.storm_rollback_frac}")


@dataclasses.dataclass(frozen=True)
class Signals:
    """One decision boundary's observations.  Every field derives from
    engine-identical state (integer counters, exact waterfall cells,
    windowed ledger accumulators), so the same rule table makes the same
    decisions on both engines."""
    t: float
    failures_delta: int           # fleet failures since the last boundary
    expected_failures: float      # nominal per-boundary expectation:
                                  # chips * period / chip_mtbf
    cum_rate_x: float             # cumulative observed failure rate over
                                  # the run, as a multiple of nominal
                                  # (0.0 until there is enough evidence)
    rollback_frac: float          # (failure_rollback + gang_stall) delta
                                  # over the period's capacity chip-time
    gang_waiting: int             # rigid gangs stalled on replacement HW
    maintenance: bool             # any pod currently drained
    queue_frac: float             # queued chip demand / fleet chips
    paper_policies: bool          # live policies == the paper combo
    sg: float                     # last ledger window's scheduling goodput
    mpg: float                    # last ledger window's MPG


@dataclasses.dataclass(frozen=True)
class Action:
    """One accepted switch.  ``mode`` is the controller mode to enter
    (None keeps the current one); ``elastic_override`` feeds
    ``FleetSim._elastic_override`` verbatim (``"keep"`` leaves it)."""
    rule: str
    mode: Optional[str] = None
    elastic_override: object = "keep"
    retune_daly: bool = False
    policies: Optional[Dict[str, str]] = None
    evict_gang_waits: bool = False


class RuleTable:
    """The deliverable: Signals -> Optional[Action], with hysteresis.

    Four rules, in precedence order:

      * **scheduler_rescue** — sustained queue overhang while running
        non-paper policies: switch the live policy objects to the paper
        combo (placement/preemption/defrag all at once);
      * **survival entry** — a failure storm (scale-aware failure-count
        trigger or rollback-loss fraction over threshold) or an active
        maintenance drain: force elastic resize on, retune Daly intervals
        from the observed failure rate, and evict stalled rigid gangs so
        they requeue elastically;
      * **gang_rescue** — outside survival, a rigid gang stalled on a
        repair window: evict it (freeing the healthy slices' chips for
        the backlog) and retune, *without* flipping the whole fleet
        elastic — one stuck gang is a local problem, not a storm;
      * **calm restore** — `calm_boundaries` consecutive boundaries below
        the (lower) exit thresholds, and a cumulative failure rate back
        near nominal: restore per-job elastic flags.

    A learned policy replaces this class wholesale — the contract is just
    ``propose(signals, mode)``.
    """

    def __init__(self, cfg: ControllerConfig):
        self.cfg = cfg
        self._calm_streak = 0
        self._queue_streak = 0

    def _storm(self, s: Signals) -> bool:
        cfg = self.cfg
        threshold = max(float(cfg.storm_failures),
                        cfg.storm_rate_x * s.expected_failures)
        return (s.failures_delta >= threshold
                or s.rollback_frac >= cfg.storm_rollback_frac)

    def propose(self, s: Signals, mode: str) -> Optional[Action]:
        cfg = self.cfg
        if not s.paper_policies and s.queue_frac >= cfg.rescue_queue_frac:
            self._queue_streak += 1
            if self._queue_streak >= cfg.rescue_boundaries:
                self._queue_streak = 0
                return Action(rule="scheduler_rescue",
                              policies=dict(PAPER_COMBO))
        else:
            self._queue_streak = 0
        if mode != "survival":
            if self._storm(s) or s.maintenance:
                self._calm_streak = 0
                rule = ("maintenance_drain"
                        if s.maintenance and not self._storm(s)
                        else "failure_storm")
                # the fleet-wide elastic flip helps when failures are the
                # dominant pressure (degraded restarts beat queueing for
                # full shapes), but during a capacity drain it makes jobs
                # squeeze into the shrunken fleet at tiny widths and pay
                # reshard churn twice — once in, once back out — so a
                # storm that arrives mid-drain rides out rigid, with
                # gang eviction + Daly retune only
                flip = True if not s.maintenance else "keep"
                return Action(rule=rule, mode="survival",
                              elastic_override=flip, retune_daly=True,
                              evict_gang_waits=True)
            if s.gang_waiting > 0:
                return Action(rule="gang_rescue", retune_daly=True,
                              evict_gang_waits=True)
            return None
        calm = (s.failures_delta == 0
                and s.rollback_frac <= cfg.calm_rollback_frac
                and s.gang_waiting == 0
                and not s.maintenance
                and s.cum_rate_x <= cfg.calm_rate_x)
        self._calm_streak = self._calm_streak + 1 if calm else 0
        if self._calm_streak >= cfg.calm_boundaries:
            self._calm_streak = 0
            return Action(rule="calm_restore", mode="baseline",
                          elastic_override=None)
        return None


class AdaptiveController:
    """Online closed-loop controller over a live :class:`FleetSim`.

    Usage (or just pass ``controller=`` to ``scenarios.build_sim``)::

        ctrl = AdaptiveController()
        sim = build_sim(scenario, ..., controller=ctrl)
        sim.run()
        ctrl.switches        # the decision log
    """

    def __init__(self, cfg: Optional[ControllerConfig] = None, rules=None):
        self.cfg = cfg if cfg is not None else ControllerConfig()
        self.rules = rules if rules is not None else RuleTable(self.cfg)
        self.mode = "baseline"
        self.switches: List[dict] = []
        self.waterfall: Optional[AttributionWaterfall] = None
        self.decide_every_s: float = 0.0
        self._sim = None
        self._last_switch_t = -math.inf
        self._prev_failures = 0
        self._prev_buckets: Dict[str, float] = {}
        # background-MTBF evidence: failures and allocated chip-time
        # accumulated over non-burst boundaries.  Correlated mass-kill
        # boundaries are excluded so a burst cannot poison the Daly
        # estimate — a fleet with healthy background MTBF that eats one
        # burst should not start checkpointing 3x as often for the rest
        # of the run.  Mild storm boundaries (a Poisson pair) DO count:
        # they are background hazard, and dropping them would bias the
        # estimate toward a healthier fleet than the one observed
        self._bg_failures = 0
        self._bg_alloc = 0.0
        self._prev_alloc = 0.0
        self._burst_seen = False

    # ---- binding ----------------------------------------------------------
    def bind(self, sim) -> "AdaptiveController":
        """Attach to ``sim`` before it runs: subscribe a fresh waterfall
        (must precede the first emitted event) and schedule the first
        decision boundary."""
        if self._sim is not None:
            raise ValueError("controller is already bound to a sim")
        self._sim = sim
        self.decide_every_s = (self.cfg.windows_per_decision
                               * sim.ledger.window)
        if self.decide_every_s <= 0:
            raise ValueError(
                "controller needs a positive ledger window to define its "
                f"decision cadence, got window={sim.ledger.window!r}")
        self.waterfall = AttributionWaterfall().attach(sim.ledger)
        sim.attach_controller(self)
        return self

    # ---- decision boundary ------------------------------------------------
    def on_boundary(self, sim) -> None:
        """One decision boundary (the sim calls this on every timed
        ``control`` event, after its engine-specific ledger sync)."""
        s = self._signals(sim)
        action = self._consider(s)
        if action is not None:
            self._apply(sim, action, s)
        # background-MTBF bookkeeping: correlated mass-kill boundaries
        # never enter the Daly evidence.  The burst predicate is
        # recomputed from cfg (not delegated to the rule table) so a
        # learned `rules` plug-in can't poison it
        cfg = self.cfg
        correlated = (s.failures_delta
                      >= max(float(cfg.burst_failures),
                             cfg.burst_rate_x * s.expected_failures))
        alloc = sim.ledger._totals.allocated
        if correlated:
            self._burst_seen = True
        else:
            self._bg_failures += s.failures_delta
            self._bg_alloc += alloc - self._prev_alloc
        self._prev_alloc = alloc
        self._prev_failures += s.failures_delta
        self._prev_buckets = self.waterfall.bucket_totals()

    def _signals(self, sim) -> "Signals":
        failures = sum(rt.failures for rt in sim.jobs.values())
        buckets = self.waterfall.bucket_totals()
        prev = self._prev_buckets

        def delta(name: str) -> float:
            return buckets.get(name, 0.0) - prev.get(name, 0.0)

        total_chips = sim.cluster.total_chips
        period_cap = total_chips * self.decide_every_s
        rollback_frac = ((delta("failure_rollback") + delta("gang_stall"))
                         / period_cap if period_cap else 0.0)
        queue_chips = sum(sim.jobs[j].spec.chips for j in sim.queue)
        rows = sim.ledger.tail_series(1, total_chips)
        last = rows[-1] if rows else {"sg": 0.0, "mpg": 0.0}
        paper = (sim.placement.name == PAPER_COMBO["placement"]
                 and sim.preemption.name == PAPER_COMBO["preemption"]
                 and sim.defrag.name == PAPER_COMBO["defrag"])
        # nominal rates come from the fleet's *spec* MTBF (SimConfig),
        # not the scenario's shock factor — the controller must infer a
        # degraded fleet from observations, not read the ground truth.
        # The cumulative comparison normalizes by *allocated* chip-time
        # (failures only strike running jobs), so low occupancy doesn't
        # read as a healthy MTBF
        expected = total_chips * self.decide_every_s / sim.cfg.chip_mtbf
        cum_rate_x = 0.0
        if failures >= self.cfg.min_failures_for_retune:
            nominal_cum = sim.ledger._totals.allocated / sim.cfg.chip_mtbf
            cum_rate_x = failures / nominal_cum if nominal_cum else 0.0
        return Signals(
            t=sim.now,
            failures_delta=failures - self._prev_failures,
            expected_failures=expected,
            cum_rate_x=cum_rate_x,
            rollback_frac=rollback_frac,
            gang_waiting=len(sim._gang_wait),
            maintenance=any(d > 0 for d in sim._maint_depth.values()),
            queue_frac=queue_chips / total_chips if total_chips else 0.0,
            paper_policies=paper,
            sg=last["sg"], mpg=last["mpg"])

    def _consider(self, s: "Signals") -> Optional[Action]:
        """Cooldown + rule table: the pure decision core (the hypothesis
        safety properties drive this method with synthetic Signals).  A
        boundary inside the cooldown proposes nothing — at most one
        accepted switch per ``cooldown_s``, structurally."""
        if s.t - self._last_switch_t < self.cfg.cooldown_s:
            return None
        action = self.rules.propose(s, self.mode)
        if action is None:
            return None
        self._last_switch_t = s.t
        if action.mode is not None:
            self.mode = action.mode
        self.switches.append({
            "t": s.t, "rule": action.rule, "mode": self.mode,
            "signals": {"failures_delta": s.failures_delta,
                        "rollback_frac": s.rollback_frac,
                        "gang_waiting": s.gang_waiting,
                        "maintenance": s.maintenance,
                        "queue_frac": s.queue_frac,
                        "sg": s.sg, "mpg": s.mpg},
        })
        return action

    # ---- action application ----------------------------------------------
    def _apply(self, sim, action: Action, s: "Signals") -> None:
        # the switch-overhead interval is emitted FIRST: the vectorized
        # engine's buffers are empty right after _control_sync, so a
        # direct ledger emit here lands in the same stream position on
        # both engines; action side-effects below may emit (buffered)
        cost = min(s.t + self.cfg.switch_cost_s, sim.cfg.horizon)
        sim.ledger.emit(
            job_id=CONTROL_JOB_ID, phase=Phase.CONTROL, t0=s.t, t1=cost,
            chips=self.cfg.switch_chips,
            segment={"layer": Layer.SCHEDULING.value,
                     "emitter": "controller", "rule": action.rule})
        if action.policies:
            sim.set_policies(**action.policies)
        if action.elastic_override != "keep":
            sim._elastic_override = action.elastic_override
        retuned = 0
        if action.retune_daly:
            retuned = self._retune_daly(sim, s)
        if action.evict_gang_waits and sim._gang_wait:
            for job_id in list(sim._gang_wait):
                sim._evict_gang_wait(job_id)
            sim._try_schedule()
        self.switches[-1]["retuned_jobs"] = retuned

    def _retune_daly(self, sim, s: "Signals") -> int:
        """Re-derive pending jobs' checkpoint intervals from the observed
        fleet failure rate (Daly's sqrt(2 * write * MTBF), the advisor's
        formula fed by live evidence instead of the configured MTBF).
        Only jobs with no open run segment are touched — an open segment's
        checkpoint-survival accounting reads the spec it started with.

        The MTBF estimate uses *background* evidence only (failures and
        allocated chip-time from non-burst boundaries): correlated
        mass-kill bursts say nothing about the exponential background
        rate Daly's formula models, and counting them shrinks intervals
        ~3x on a healthy fleet.  Direction is burst-gated: on a fleet
        that has never shown a correlated burst the retune moves freely
        toward the Daly optimum (lengthening a miscalibrated
        too-frequent interval is a pure overhead win there), but once
        one mass-kill boundary has been seen it only ever *shortens* —
        the configured interval is the operator's prior on correlated
        risk, and lengthening it on "healthy background" evidence walks
        straight into the next burst."""
        if (self._bg_failures < self.cfg.min_failures_for_retune
                or self._bg_alloc <= 0):
            return 0
        chip_mtbf_obs = self._bg_alloc / self._bg_failures
        retuned = 0
        for job_id, rt in sim.jobs.items():
            if job_id in sim.running or rt.remaining <= 0:
                continue
            spec = rt.spec
            write = (sim.cfg.async_snapshot_pause if spec.async_checkpoint
                     else spec.checkpoint_write)
            mtbf = chip_mtbf_obs / spec.chips
            cap = (spec.checkpoint_interval if self._burst_seen
                   else 86400.0)
            interval = max(60.0, min(cap, math.sqrt(2.0 * write * mtbf)))
            if interval != spec.checkpoint_interval:
                rt.spec = dataclasses.replace(
                    spec, checkpoint_interval=interval)
                retuned += 1
        return retuned
