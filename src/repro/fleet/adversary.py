"""Adversarial scenario search: find fleet conditions that break the loop.

MAD-Max-style design-space exploration under stress (PAPERS.md): instead
of scoring the controller on the 7 friendly presets, a seeded
random-restart hill-climber mutates :class:`~repro.fleet.scenarios.Scenario`
parameters — burst count/severity/timing, fleet-wide MTBF shocks,
maintenance-drain placement, arrival warp, repair-window scale — to
*minimize* the controlled fleet's MPG.  The resulting worst-case suite is
committed (``BENCH_controller.json``) and re-evaluated exactly in CI: the
controller must keep MPG at or above the best static policy's floor on
every scenario the search finds.

The search is deliberately simple and fully deterministic:

  * a **genome** is a flat dict of rounded scalars (rounded at creation,
    so a committed genome re-evaluates to the exact same floats later);
  * :func:`scenario_from` compiles a genome into a frozen ``Scenario``
    (plus the repair scale, which lives beside the scenario because
    ``slice_repair_s`` is a sim knob, not a scenario field);
  * :func:`search_worst` runs ``restarts`` independent seeded
    hill-climbs, each mutating one gene per step and keeping the mutant
    only when it strictly lowers the evaluated MPG; an evaluation cache
    keyed on the canonical genome makes revisits free.

The evaluator is injected (``evaluate(genome) -> mpg``) so the benchmark
controls the fleet scale and which arm — controlled or static — the
search attacks.
"""
from __future__ import annotations

import random
from typing import Callable, Dict, List, Tuple

from repro.fleet.scenarios import (ArrivalModulation, FailureBurst,
                                   MaintenanceWindow, Scenario)

Genome = Dict[str, object]

# gene -> (low, high) for numeric genes; categorical genes listed below.
# Bounds stay inside regions the sim treats meaningfully: burst times and
# maintenance windows inside the horizon, MTBF shocks from quarter-life
# to better-than-nominal, repair windows from 30 min to 8 h of scale.
BOUNDS: Dict[str, Tuple[float, float]] = {
    "n_bursts": (0, 4),               # int: correlated failure shocks
    "kill_frac": (0.10, 0.60),        # P(running job dies) per shock
    "first_frac": (0.10, 0.60),       # first shock, fraction of horizon
    "every_frac": (0.05, 0.30),       # shock spacing, fraction of horizon
    "mtbf_factor": (0.25, 1.50),      # fleet-wide MTBF multiplier
    "maint_pods": (0, 2),             # int: staggered drain windows
    "maint_start_frac": (0.10, 0.60),
    "maint_width_frac": (0.05, 0.25),
    "arrival_amplitude": (0.00, 0.90),   # diurnal swing
    "arrival_gain": (1.0, 8.0),          # bursty spike gain
    "repair_hours": (0.5, 8.0),          # slice_repair_s scale, hours
}
ARRIVAL_KINDS = ("uniform", "diurnal", "bursty")
_INT_GENES = ("n_bursts", "maint_pods")
_ROUND = 4


def _clamp(gene: str, value: float) -> float:
    lo, hi = BOUNDS[gene]
    v = min(hi, max(lo, value))
    if gene in _INT_GENES:
        return int(round(v))
    return round(v, _ROUND)


def random_genome(rng: random.Random) -> Genome:
    """One uniform sample of the search space (rounded, so committing the
    genome and re-evaluating it later reproduces the same scenario)."""
    g: Genome = {}
    for gene, (lo, hi) in BOUNDS.items():
        if gene in _INT_GENES:
            g[gene] = rng.randint(int(lo), int(hi))
        else:
            g[gene] = round(rng.uniform(lo, hi), _ROUND)
    g["arrival_kind"] = rng.choice(ARRIVAL_KINDS)
    return g


def mutate(genome: Genome, rng: random.Random) -> Genome:
    """Perturb exactly one gene: gaussian step for scalars (10% of the
    range), +/-1 for integer genes, re-draw for the categorical."""
    g = dict(genome)
    gene = rng.choice(sorted(g))
    if gene == "arrival_kind":
        g[gene] = rng.choice([k for k in ARRIVAL_KINDS if k != g[gene]])
    elif gene in _INT_GENES:
        lo, hi = BOUNDS[gene]
        step = rng.choice((-1, 1))
        g[gene] = int(min(hi, max(lo, g[gene] + step)))
    else:
        lo, hi = BOUNDS[gene]
        g[gene] = _clamp(gene, g[gene] + rng.gauss(0.0, 0.10 * (hi - lo)))
    return g


def genome_key(genome: Genome) -> Tuple:
    """Canonical hashable identity (the evaluation-cache key)."""
    return tuple(sorted(genome.items()))


def scenario_from(genome: Genome, name: str = "adversarial") -> Scenario:
    """Compile a genome into a frozen Scenario.  ``repair_hours`` is NOT
    encoded here — it maps to the ``slice_repair_s`` sim knob
    (``genome["repair_hours"] * 3600``), which the evaluator passes to
    ``build_sim`` alongside the scenario."""
    kind = genome["arrival_kind"]
    if kind == "diurnal":
        arrival = ArrivalModulation(kind="diurnal",
                                    amplitude=genome["arrival_amplitude"])
    elif kind == "bursty":
        arrival = ArrivalModulation(kind="bursty",
                                    burst_gain=genome["arrival_gain"])
    else:
        arrival = ArrivalModulation()
    bursts = tuple(
        FailureBurst(
            at_frac=round(min(0.95, genome["first_frac"]
                              + i * genome["every_frac"]), _ROUND),
            kill_frac=genome["kill_frac"])
        for i in range(int(genome["n_bursts"])))
    maint = tuple(
        MaintenanceWindow(
            pod=i,
            start_frac=round(min(0.90, genome["maint_start_frac"]
                                 + i * genome["maint_width_frac"]), _ROUND),
            end_frac=round(min(0.98, genome["maint_start_frac"]
                               + (i + 1) * genome["maint_width_frac"]),
                           _ROUND))
        for i in range(int(genome["maint_pods"])))
    return Scenario(name=name,
                    description="adversarially-searched worst case",
                    arrival=arrival, maintenance=maint, bursts=bursts,
                    mtbf_factor=genome["mtbf_factor"])


def search_worst(evaluate: Callable[[Genome], float], *, seed: int,
                 restarts: int = 3, steps: int = 10,
                 keep: int = 3) -> List[Dict[str, object]]:
    """Random-restart hill-climb minimizing ``evaluate(genome)``.

    Each restart draws a fresh random genome from its own seeded stream
    (``random.Random(f"{seed}:adversary:{r}")``), then takes ``steps``
    single-gene mutations, accepting only strict improvements (lower
    MPG).  Returns the ``keep`` distinct worst genomes found across all
    restarts, sorted ascending by MPG::

        [{"genome": {...}, "mpg": 0.21}, ...]
    """
    cache: Dict[Tuple, float] = {}

    def ev(g: Genome) -> float:
        k = genome_key(g)
        if k not in cache:
            cache[k] = evaluate(g)
        return cache[k]

    seen: Dict[Tuple, Genome] = {}
    for r in range(restarts):
        rng = random.Random(f"{seed}:adversary:{r}")
        cur = random_genome(rng)
        cur_mpg = ev(cur)
        seen.setdefault(genome_key(cur), cur)
        for _ in range(steps):
            cand = mutate(cur, rng)
            cand_mpg = ev(cand)
            seen.setdefault(genome_key(cand), cand)
            if cand_mpg < cur_mpg:
                cur, cur_mpg = cand, cand_mpg
    ranked = sorted(seen.values(), key=lambda g: (cache[genome_key(g)],
                                                  genome_key(g)))
    return [{"genome": g, "mpg": cache[genome_key(g)]}
            for g in ranked[:keep]]
