"""Declarative fleet scenarios (paper §3–§5's observed conditions).

The paper's fleet analysis draws its power from *diverse* operating
conditions — diurnal demand, scheduled maintenance, correlated failure
domains, heterogeneous hardware generations.  A :class:`Scenario` is a
frozen, declarative description of one such condition set:

  * arrival modulation (:class:`ArrivalModulation`): diurnal / bursty
    intensity profiles warped onto the workload's uniform arrival draws;
  * scheduled maintenance (:class:`MaintenanceWindow`): pods drained
    (checkpoint-resume) for a window, capacity booked as SG loss;
  * correlated failure bursts (:class:`FailureBurst`) and MTBF shocks:
    the paper's failure-domain events, beyond independent chip failures;
  * heterogeneous pod generations: per-generation peak-FLOPS factors that
    weight Program Goodput (``repro.core.goodput.generation_pg_weights``).

Times are *fractions of the sim horizon*, so one preset scales from the
tiny golden-trace configuration to paper-scale sweeps unchanged.

Presets live in :data:`SCENARIOS`; modifiers (``diurnal()``, ``bursty()``,
``maintenance_wave()``, ``failure_storm()``, ``hetero()``) are composable —
each returns a new Scenario, so ``STEADY.diurnal().hetero()`` is itself a
valid scenario.  :func:`build_sim` turns (scenario, knobs) into a ready
``FleetSim`` with a deterministic, hermetic workload (explicit pg table;
every random stream seeded per component).
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.ledger import GoodputLedger
from repro.fleet.sim import FleetSim, SimConfig
from repro.fleet.workload import generate_jobs, make_warp


@dataclasses.dataclass(frozen=True)
class ArrivalModulation:
    """Multiplicative arrival-intensity profile over sim time.

    kinds:
      * ``uniform`` — constant intensity (the seed workload);
      * ``diurnal`` — ``1 + amplitude * sin(2*pi*t/period + phase)``;
      * ``bursty``  — baseline 1, plus ``gain`` inside periodic windows of
        ``burst_width`` seconds every ``burst_every`` seconds.
    """
    kind: str = "uniform"
    amplitude: float = 0.0            # diurnal: in [0, 1)
    period: float = 86400.0           # diurnal period (s)
    phase: float = -math.pi / 2       # diurnal phase (trough at t=0)
    burst_every: float = 6 * 3600.0
    burst_width: float = 1800.0
    burst_gain: float = 4.0

    def intensity(self, t: float) -> float:
        if self.kind == "diurnal":
            return 1.0 + self.amplitude * math.sin(
                2 * math.pi * t / self.period + self.phase)
        if self.kind == "bursty":
            in_burst = (t % self.burst_every) < self.burst_width
            return 1.0 + (self.burst_gain if in_burst else 0.0)
        return 1.0


@dataclasses.dataclass(frozen=True)
class MaintenanceWindow:
    """Drain ``pod`` (modulo the sim's pod count) over a horizon-relative
    window: occupants are checkpoint-migrated out, the pod is reserved."""
    pod: int
    start_frac: float
    end_frac: float

    def __post_init__(self):
        if not 0.0 <= self.start_frac < self.end_frac:
            # an inverted window would fire maint_end before maint_start
            # and leave the pod reserved until the horizon
            raise ValueError(
                f"maintenance window needs 0 <= start_frac < end_frac, "
                f"got [{self.start_frac}, {self.end_frac}]")


@dataclasses.dataclass(frozen=True)
class FailureBurst:
    """A correlated failure shock at ``at_frac`` of the horizon: each
    running job fails independently with probability ``kill_frac``."""
    at_frac: float
    kill_frac: float

    def __post_init__(self):
        if self.at_frac < 0.0 or self.kill_frac < 0.0:
            raise ValueError(
                f"failure burst needs at_frac >= 0 and kill_frac >= 0, "
                f"got at_frac={self.at_frac}, kill_frac={self.kill_frac}")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named, declarative fleet condition set (see module docstring)."""
    name: str
    description: str = ""
    arrival: ArrivalModulation = ArrivalModulation()
    maintenance: Tuple[MaintenanceWindow, ...] = ()
    bursts: Tuple[FailureBurst, ...] = ()
    mtbf_factor: float = 1.0          # <1 = failure-prone period
    pod_generations: Tuple[str, ...] = ()   # cycled over pods; () = uniform
    target_load: float = 0.70

    # -- composable modifiers (each returns a new Scenario) ----------------
    def named(self, name: str, description: str = "") -> "Scenario":
        return dataclasses.replace(self, name=name,
                                   description=description or self.description)

    def _set_arrival(self, suffix: str,
                     arrival: ArrivalModulation) -> "Scenario":
        if self.arrival.kind != "uniform":
            # the single arrival slot would silently swallow the earlier
            # modulation while the name still advertised both — refuse
            raise ValueError(
                f"scenario {self.name!r} already has a "
                f"{self.arrival.kind!r} arrival modulation; compose at "
                "most one of diurnal()/bursty()")
        return dataclasses.replace(self, name=f"{self.name}+{suffix}",
                                   arrival=arrival)

    def diurnal(self, amplitude: float = 0.6,
                period: float = 86400.0) -> "Scenario":
        return self._set_arrival(
            "diurnal", ArrivalModulation(kind="diurnal",
                                         amplitude=amplitude,
                                         period=period))

    def bursty(self, gain: float = 4.0, every: float = 6 * 3600.0,
               width: float = 1800.0) -> "Scenario":
        return self._set_arrival(
            "bursty", ArrivalModulation(kind="bursty", burst_gain=gain,
                                        burst_every=every,
                                        burst_width=width))

    def maintenance_wave(self, pods: int = 2, start_frac: float = 0.35,
                         width_frac: float = 0.10,
                         stagger_frac: float = 0.12) -> "Scenario":
        """Rolling maintenance: ``pods`` staggered drain windows."""
        wins = tuple(
            MaintenanceWindow(pod=i,
                              start_frac=start_frac + i * stagger_frac,
                              end_frac=start_frac + i * stagger_frac
                              + width_frac)
            for i in range(pods))
        return dataclasses.replace(self, name=f"{self.name}+maint",
                                   maintenance=self.maintenance + wins)

    def failure_storm(self, bursts: int = 3, kill_frac: float = 0.35,
                      first_frac: float = 0.30, every_frac: float = 0.15,
                      mtbf_factor: float = 0.5) -> "Scenario":
        """Correlated failure bursts plus a fleet-wide MTBF shock."""
        shocks = tuple(
            FailureBurst(at_frac=first_frac + i * every_frac,
                         kill_frac=kill_frac)
            for i in range(bursts))
        return dataclasses.replace(self, name=f"{self.name}+storm",
                                   bursts=self.bursts + shocks,
                                   mtbf_factor=self.mtbf_factor * mtbf_factor)

    def hetero(self, generations: Tuple[str, ...] = ("tpu-v5p", "tpu-v5e",
                                                     "tpu-v4")) -> "Scenario":
        return dataclasses.replace(self, name=f"{self.name}+hetero",
                                   pod_generations=tuple(generations))

    def load(self, target_load: float) -> "Scenario":
        return dataclasses.replace(self, name=f"{self.name}+load",
                                   target_load=target_load)


# ---------------------------------------------------------------------------
# named presets (the scenario_sweep benchmark and golden traces run these)
# ---------------------------------------------------------------------------

STEADY = Scenario(
    "steady", "uniform arrivals, homogeneous fleet, base MTBF — the seed "
              "workload the repo exercised before scenarios existed")

SCENARIOS: Dict[str, Scenario] = {s.name: s for s in (
    STEADY,
    STEADY.diurnal().named(
        "diurnal", "day/night demand swing (paper Fig. 5 timelines)"),
    STEADY.bursty().named(
        "bursty", "batched submission spikes every 6h"),
    STEADY.maintenance_wave().named(
        "maintenance", "rolling 2-pod drain windows mid-horizon"),
    STEADY.failure_storm().named(
        "failure_storm", "3 correlated failure bursts + halved MTBF"),
    STEADY.hetero().named(
        "hetero_fleet", "v4/v5e/v5p pod generations; PG weighted by peak "
                        "FLOPS ratios"),
    STEADY.diurnal().failure_storm().hetero().named(
        "peak_week", "compound stress: diurnal load + failure storm on a "
                     "heterogeneous fleet"),
)}


# ---------------------------------------------------------------------------
# sim factory
# ---------------------------------------------------------------------------

def build_sim(scenario: Scenario, *, n_jobs: int = 200, seed: int = 0,
              n_pods: int = 8, pod_size: int = 256,
              horizon: float = 7 * 24 * 3600.0,
              placement: str = "best_fit", preemption: str = "protect_xl",
              defrag: str = "drain_for_xl", retain_intervals: bool = False,
              ledger: Optional[GoodputLedger] = None,
              pg_table: Optional[Dict[str, float]] = None,
              size_mix: Optional[Dict[str, float]] = None,
              job_mutator: Optional[Callable] = None,
              engine: str = "vectorized",
              sample_dt: Optional[float] = None,
              slice_repair_s: float = 0.0,
              controller=None) -> FleetSim:
    """A ready-to-run ``FleetSim`` for one scenario.

    Hermetic by construction: the pg table defaults to ``{}`` (per-arch PG
    then comes from the workload's seeded rng, not from whatever roofline
    artifacts happen to be on disk), so the same (scenario, seed, knobs)
    always yields a byte-identical event trace.

    ``job_mutator`` rewrites each generated ``JobSpec`` before submission
    — the hook the what-if advisor (``repro.fleet.advisor``) uses to
    apply counterfactual knobs (async checkpointing, warm compile cache,
    ...) to an otherwise byte-identical workload.

    ``controller`` binds an online ``repro.fleet.controller``
    ``AdaptiveController`` onto the sim (its attribution waterfall
    attaches before any event, so it must bind at build time).
    """
    cfg = SimConfig(n_pods=n_pods, pod_size=pod_size, horizon=horizon,
                    seed=seed, placement=placement, preemption=preemption,
                    defrag=defrag, retain_intervals=retain_intervals,
                    engine=engine, sample_dt=sample_dt,
                    slice_repair_s=slice_repair_s,
                    scenario=scenario)
    sim = FleetSim(cfg, ledger=ledger)
    profile = (scenario.arrival.intensity
               if scenario.arrival.kind != "uniform" else None)
    jobs = generate_jobs(n_jobs, horizon, seed=seed,
                         size_mix=size_mix,
                         pg_table={} if pg_table is None else pg_table,
                         capacity_chips=n_pods * pod_size,
                         target_load=scenario.target_load,
                         arrival_profile=profile)
    if job_mutator is not None:
        jobs = [job_mutator(j) for j in jobs]
    for j in jobs:
        sim.submit(j)
    # workload provenance, recorded into trace headers so a trace alone
    # suffices to rebuild this exact sim (repro.fleet.advisor.from_trace).
    # size_mix is stored as an ordered pair list: the workload's _pick
    # walks the mix in insertion order, and trace JSON sorts dict keys —
    # a round-tripped plain dict would silently reshuffle the workload
    sim.workload_info = {
        "n_jobs": n_jobs,
        "size_mix": (None if size_mix is None
                     else [[k, v] for k, v in size_mix.items()]),
        "pg_table": sorted((pg_table or {}).items()),
    }
    if controller is not None:
        controller.bind(sim)
    return sim


# Tiny configuration for the golden-trace regression suite: small enough
# that one trace is a few KB, busy enough that every phase kind appears.
GOLDEN_SEED = 1234
GOLDEN_KNOBS = dict(n_jobs=24, seed=GOLDEN_SEED, n_pods=2, pod_size=64,
                    horizon=24 * 3600.0, retain_intervals=False)
# small/medium only: with 2 pods of 64 chips every size the workload can
# draw is schedulable, so no job idles in the queue past the horizon
GOLDEN_SIZE_MIX = {"small": 0.60, "medium": 0.40}


def golden_sim(preset: str, engine: str = "vectorized") -> FleetSim:
    """The exact sim configuration behind ``tests/golden/<preset>.jsonl``.

    ``engine`` selects the event core; both engines must produce the same
    bytes (the equivalence gate in ``tests/test_golden_traces.py``)."""
    if preset not in SCENARIOS:
        raise ValueError(f"unknown scenario preset {preset!r}; "
                         f"choose from {sorted(SCENARIOS)}")
    return build_sim(SCENARIOS[preset], size_mix=GOLDEN_SIZE_MIX,
                     engine=engine, **GOLDEN_KNOBS)


def preset_names() -> List[str]:
    return sorted(SCENARIOS)


# ---------------------------------------------------------------------------
# serving arrivals (the serve engine reuses the fleet arrival processes)
# ---------------------------------------------------------------------------

def request_arrivals(n: int, span: float, seed: int = 0,
                     arrival: ArrivalModulation = ArrivalModulation()
                     ) -> List[float]:
    """Deterministic inference-request arrival times over ``[0, span)``.

    Exactly the job-arrival machinery reused at serving granularity:
    seeded uniform draws warped through the modulation's inverse
    cumulative intensity (``repro.fleet.workload.make_warp``), so the
    serve engine sees the same diurnal/bursty demand shapes the fleet
    simulator does — ``request_arrivals(n, span,
    arrival=SCENARIOS["bursty"].arrival)`` is the Fig. 15 serving
    condition.  Returned sorted (a queue, not a job table)."""
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if span <= 0 and n:
        raise ValueError(f"span must be positive, got {span}")
    rng = random.Random(seed)
    us = [rng.uniform(0.0, span) for _ in range(n)]
    if arrival.kind != "uniform":
        warp = make_warp(arrival.intensity, span)
        us = [warp(u) for u in us]
    return sorted(us)
