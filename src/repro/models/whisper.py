"""Whisper-medium backbone (arXiv:2212.04356): encoder-decoder transformer.

The conv1d audio frontend is STUBBED per the assignment: ``input_specs``
provides precomputed frame embeddings (b, 1500, d).  LayerNorm + GELU MLP,
learned decoder positions (extended to 32k for the decode_32k backbone
exercise — deviation noted in DESIGN.md), pre-norm, tied output projection.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.attention import (cross_attention, decode_self_attention,
                                    self_attention)
from repro.models.config import ModelConfig
from repro.models.layers import (dense, embed_tokens, layernorm, lm_logits,
                                 mlp, softmax_xent)
from repro.parallel.ctx import shard_activation

PyTree = Any


def _ln(x, bp, name, cfg):
    return layernorm(x, bp[name], bp[f"{name}_b"], cfg.norm_eps)


def _sinusoid(positions: int, d: int):
    half = d // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half) / (half - 1))
    t = jnp.arange(positions)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


def encode(params, frames, cfg: ModelConfig):
    """frames: (b, T=1500, d) precomputed conv-frontend output (stub)."""
    x = frames.astype(cfg.compute_dtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(cfg.compute_dtype)
    x = shard_activation(x, "act")

    def body(h, bp):
        h = shard_activation(h, "act")
        a, _ = self_attention(_ln(h, bp, "ln1", cfg), bp["attn"], cfg,
                              causal=False, use_rope=False)
        h = h + a
        h = h + mlp(_ln(h, bp, "ln2", cfg), bp["mlp"], cfg)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:
        for i in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc_blocks"]))
    return layernorm(x, params["final_norm_enc"], params["final_norm_enc_b"],
                     cfg.norm_eps)


def _dec_block(x, bp, cfg, enc_kv, pos_offset=0, cache=None):
    """One decoder block (train path when cache is None)."""
    x = shard_activation(x, "act")
    if cache is None:
        a, kv = self_attention(_ln(x, bp, "ln1", cfg), bp["attn"], cfg,
                               causal=True, use_rope=False)
        new_cache = kv
    else:
        st = {"k": cache["k"], "v": cache["v"], "pos": cache["pos"]}
        a, st = decode_self_attention(_ln(x, bp, "ln1", cfg), bp["attn"], cfg,
                                      st, use_rope=False)
        new_cache = (st["k"], st["v"])
    x = x + a
    k_enc, v_enc = enc_kv
    x = x + cross_attention(_ln(x, bp, "ln_x", cfg), bp["xattn"], cfg,
                            k_enc, v_enc)
    x = x + mlp(_ln(x, bp, "ln2", cfg), bp["mlp"], cfg)
    return x, new_cache


def _enc_kv(bp, enc_out, cfg):
    b, t, _ = enc_out.shape
    k = dense(enc_out, bp["xattn"]["wk"], bp["xattn"].get("bk")).reshape(
        b, t, cfg.num_kv_heads, cfg.head_dim)
    v = dense(enc_out, bp["xattn"]["wv"], bp["xattn"].get("bv")).reshape(
        b, t, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def decode_train(params, tokens, enc_out, cfg: ModelConfig,
                 collect_caches=False, pos_offset=0):
    b, s = tokens.shape
    x = embed_tokens(tokens, params["embed"]["tok"], cfg.compute_dtype)
    pos = params["embed"]["pos_dec"][pos_offset:pos_offset + s]
    x = x + pos.astype(cfg.compute_dtype)

    def body(h, bp):
        enc_kv = _enc_kv(bp, enc_out, cfg)
        h, kv = _dec_block(h, bp, cfg, enc_kv)
        return h, (kv if collect_caches else None)

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, kvs = jax.lax.scan(body, x, params["dec_blocks"])
    else:
        kvs = []
        for i in range(cfg.num_layers):
            x, kv = body(x, jax.tree.map(lambda a: a[i], params["dec_blocks"]))
            kvs.append(kv)
        if collect_caches:
            kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
    x = layernorm(x, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
    return x, kvs


def loss_fn(params, batch, cfg: ModelConfig):
    """batch: frames (b, 1500, d), tokens (b, s)."""
    enc_out = encode(params, batch["frames"], cfg)
    x, _ = decode_train(params, batch["tokens"], enc_out, cfg)
    logits = lm_logits(x[:, :-1], params, cfg)
    logits = shard_activation(logits, "logits")
    loss = softmax_xent(logits, batch["tokens"][:, 1:])
    return loss, {"xent": loss}


def prefill(params, batch, cfg: ModelConfig, max_len: int = 0):
    """Encode audio + run the prompt through the decoder; build decode cache."""
    from repro.models.transformer import ring_place

    enc_out = encode(params, batch["frames"], cfg)
    seq = batch["tokens"].shape[1]
    max_len = max_len or seq + 64
    x, kvs = decode_train(params, batch["tokens"], enc_out, cfg,
                          collect_caches=True)
    logits = lm_logits(x[:, -1:], params, cfg)[:, 0]
    k_st, v_st = kvs
    cache = {
        "pos": jnp.asarray(seq, jnp.int32),
        "blocks": {"k": ring_place(k_st.astype(cfg.compute_dtype), seq, max_len, 2),
                   "v": ring_place(v_st.astype(cfg.compute_dtype), seq, max_len, 2)},
        "enc_out": enc_out,
    }
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, abstract=False):
    def arr(shape, dtype):
        return (jax.ShapeDtypeStruct(shape, dtype) if abstract
                else jnp.zeros(shape, dtype))

    dt = cfg.compute_dtype
    return {
        "pos": arr((), jnp.int32),
        "blocks": {
            "k": arr((cfg.num_layers, batch, seq_len, cfg.num_kv_heads,
                      cfg.head_dim), dt),
            "v": arr((cfg.num_layers, batch, seq_len, cfg.num_kv_heads,
                      cfg.head_dim), dt),
        },
        "enc_out": arr((batch, cfg.encoder_positions, cfg.d_model), dt),
    }


def decode_step(params, token, cache, cfg: ModelConfig):
    """One decoder token with self-cache + cross-attention to enc_out."""
    pos = cache["pos"]
    x = embed_tokens(token[:, None], params["embed"]["tok"], cfg.compute_dtype)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["embed"]["pos_dec"], pos, 1, axis=0).astype(cfg.compute_dtype)
    enc_out = cache["enc_out"]

    from repro.models.attention import (decode_attention, merge_heads_out,
                                        project_qkv)

    ks0, vs0 = cache["blocks"]["k"], cache["blocks"]["v"]
    b = x.shape[0]
    s_slots = ks0.shape[2]
    slot = pos % s_slots
    n_valid = jnp.minimum(pos + 1, s_slots)

    def body(i, carry):
        # fori_loop + DUS keeps the donated cache aliased in-place.
        h, ks, vs = carry
        bp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            params["dec_blocks"])
        hn = _ln(h, bp, "ln1", cfg)
        positions = jnp.full((b, 1), pos, jnp.int32)
        q, k, v = project_qkv(hn, bp["attn"], cfg, positions, use_rope=False)
        ks = jax.lax.dynamic_update_slice(
            ks, k.astype(ks.dtype).reshape(1, b, 1, *k.shape[2:]),
            (i, 0, slot, 0, 0))
        vs = jax.lax.dynamic_update_slice(
            vs, v.astype(vs.dtype).reshape(1, b, 1, *v.shape[2:]),
            (i, 0, slot, 0, 0))
        k_cache = jax.lax.dynamic_index_in_dim(ks, i, 0, keepdims=False)
        v_cache = jax.lax.dynamic_index_in_dim(vs, i, 0, keepdims=False)
        o = decode_attention(q, k_cache, v_cache, n_valid)
        h = h + merge_heads_out(o, bp["attn"])
        k_enc, v_enc = _enc_kv(bp, enc_out, cfg)
        h = h + cross_attention(_ln(h, bp, "ln_x", cfg), bp["xattn"], cfg,
                                k_enc, v_enc)
        h = h + mlp(_ln(h, bp, "ln2", cfg), bp["mlp"], cfg)
        return h, ks, vs

    if cfg.unroll_loops:   # cost-reference compiles (core.costref)
        carry = (x, ks0, vs0)
        for i in range(cfg.num_layers):
            carry = body(jnp.asarray(i), carry)
        x, ks, vs = carry
    else:
        x, ks, vs = jax.lax.fori_loop(0, cfg.num_layers, body, (x, ks0, vs0))
    x = layernorm(x, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
    logits = lm_logits(x[:, -1], params, cfg)
    return logits, {"pos": pos + 1, "blocks": {"k": ks, "v": vs},
                    "enc_out": enc_out}
