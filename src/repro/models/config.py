"""Model configuration covering every assigned architecture family.

One dataclass describes dense / MoE / hybrid (RG-LRU) / SSM (RWKV6) /
enc-dec (Whisper) / VLM-backbone (LLaVA) transformers.  Per-arch files in
``repro.configs`` instantiate these with the exact published numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (exact values live in repro/configs)."""

    name: str = "model"
    family: str = "dense"  # dense | moe | hybrid | ssm | encdec | vlm

    # Trunk
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2
    num_kv_heads: int = 2
    head_dim: int = 0          # 0 -> d_model // num_heads
    d_ff: int = 256
    vocab_size: int = 256

    # Attention
    attention_window: int = 0   # 0 -> full attention; >0 -> sliding window
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # hybrid models: every `attn_every`-th block is attention, rest recurrent.
    attn_every: int = 0         # 0 -> all attention

    # Norm / MLP
    norm_eps: float = 1e-6
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    mlp_activation: str = "silu"    # silu | gelu  (gated for silu/gelu-glu)
    mlp_gated: bool = True
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    first_k_dense: int = 0          # leading layers use a dense FFN
    d_ff_dense: int = 0             # d_ff of those dense layers (0 -> d_ff)
    router_renormalize: bool = True
    capacity_factor: float = 1.25
    moe_impl: str = "gspmd"         # gspmd (jit+GSPMD) | ep (shard_map all-to-all)

    # Recurrent (RG-LRU) blocks — RecurrentGemma
    lru_width: int = 0              # 0 -> d_model
    conv_width: int = 4

    # RWKV6
    rwkv_head_dim: int = 64

    # Encoder-decoder (Whisper)
    encoder_layers: int = 0
    encoder_positions: int = 0      # e.g. 1500 audio frames (stubbed frontend)

    # VLM backbone (LLaVA) — patch embeddings are provided pre-computed.
    num_patches: int = 0

    # Numerics
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16

    # Performance knobs (hillclimb levers; defaults = paper-faithful baseline)
    attn_chunk: int = 1024          # query-block size for chunked attention
    remat: bool = True              # rematerialize each block in train_step
    scan_layers: bool = True        # lax.scan over stacked homogeneous layers
    seq_shard_activations: bool = True  # Megatron-style sequence parallelism
    unroll_loops: bool = False      # unroll scans (cost-reference compiles:
    #   cost_analysis counts while bodies once — see core.roofline)
    loss_chunk: int = 0             # seq-chunked cross-entropy (never
    #   materializes the full (b, s, vocab) logits tensor)
    microbatches: int = 1           # gradient-accumulation microbatches
    decode_unroll: bool = False     # unroll decode layers with per-layer
    #   cache leaves: donated caches alias input->output directly (no loop
    #   carry double-buffering; EXPERIMENTS §Perf decode iteration)
    attn_kv_gather: bool = False    # replicate K/V across the model axis for
    #   attention (one all-gather/layer instead of per-chunk partial-sum
    #   all-reduces when the residual stream is sequence-sharded)
    bf16_grad_reduce: bool = False  # cast weight-grad dots to bf16 before
    #   the data-parallel all-reduce (2x collective bytes; fp32 master
    #   weights keep the update exact)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family == "hybrid" and self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # ---- derived ------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def is_attention_layer(self, layer_idx: int) -> bool:
        """Hybrid models: attention every `attn_every` blocks (else recurrent)."""
        if self.family != "hybrid" or self.attn_every <= 0:
            return True
        return (layer_idx % self.attn_every) == (self.attn_every - 1)

    def is_moe_layer(self, layer_idx: int) -> bool:
        return self.num_experts > 0 and layer_idx >= self.first_k_dense

    @property
    def sub_quadratic(self) -> bool:
        """True when a 500k-token decode is feasible (windowed or attn-free)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return self.attention_window > 0
        return self.attention_window > 0

    def num_params(self) -> int:
        """Exact parameter count from the parameter specs."""
        from repro.models.init import param_specs

        import math

        total = 0
        for spec in param_specs(self).values():
            total += math.prod(spec.shape)
        return total

    def num_active_params(self) -> int:
        """Parameters touched per token (MoE activates top-k experts only)."""
        if self.num_experts == 0:
            return self.num_params()
        from repro.models.init import param_specs

        import math

        total = 0
        for name, spec in param_specs(self).items():
            n = math.prod(spec.shape)
            if ".experts." in name or name.endswith("w_router"):
                # routed expert weights: only top-k of E participate per token
                if ".experts." in name:
                    n = n * self.experts_per_token // self.num_experts
            total += n
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment matrix."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, plus the reason when skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full quadratic attention: 500k decode infeasible (DESIGN.md §5)"
    return True, ""
