from repro.models.config import (ModelConfig, ShapeConfig, SHAPES,
                                 SHAPES_BY_NAME, shape_applicable)  # noqa: F401
