"""Mixture-of-Experts FFN: top-k routing with capacity-bounded, sort-based
dispatch (token-drop on overflow, GShard-style).

Two implementations share the router:
  * ``moe_gspmd``  — plain jit; XLA/GSPMD chooses collectives (baseline).
  * ``repro.parallel.moe_ep.moe_ep`` — shard_map expert-parallel all-to-all
    (production path, selected with cfg.moe_impl == "ep").

FLOP cost is proportional to *active* params (capacity-bounded batched
matmul), not total experts — the dense-einsum dispatch trap is avoided by
sort+gather, which is O(T log T) data movement and zero matmul FLOPs.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense


def router_topk(x2d, router_w, cfg: ModelConfig):
    """x2d: (T, d) -> gates (T, k) fp32, expert idx (T, k) int32, aux loss."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    if cfg.router_renormalize:
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    # Switch-style load-balancing auxiliary loss
    e = cfg.num_experts
    me = jnp.mean(jax.nn.one_hot(idx[:, 0], e), axis=0)       # fraction routed
    ce = jnp.mean(probs, axis=0)                              # mean router prob
    aux = e * jnp.sum(me * ce)
    return gates, idx, aux


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.experts_per_token
                      / cfg.num_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def build_dispatch(idx, n_tokens: int, cap: int, cfg: ModelConfig):
    """Sort assignments by expert; compute (expert, slot) for each (token, k).

    Returns sorted token ids, expert ids, slot-in-expert, and keep mask
    (slot < capacity); all shape (T*k,).
    """
    k = cfg.experts_per_token
    flat_e = idx.reshape(-1)                                 # (T*k,)
    order = jnp.argsort(flat_e)                              # stable
    tok = (jnp.arange(n_tokens * k) // k)[order]
    e_sorted = flat_e[order]
    starts = jnp.searchsorted(e_sorted, jnp.arange(cfg.num_experts))
    slot = jnp.arange(n_tokens * k) - starts[e_sorted]
    keep = slot < cap
    return tok, e_sorted, slot, keep, order


def expert_ffn(xe, experts, cfg: ModelConfig):
    """xe: (E, C, d) batched through each expert's gated MLP -> (E, C, d)."""
    act = jax.nn.silu if cfg.mlp_activation == "silu" else jax.nn.gelu
    h = jnp.einsum("ecd,edf->ecf", xe, experts["wi"].astype(xe.dtype),
                   preferred_element_type=jnp.float32).astype(xe.dtype)
    g = jnp.einsum("ecd,edf->ecf", xe, experts["wg"].astype(xe.dtype),
                   preferred_element_type=jnp.float32).astype(xe.dtype)
    h = act(g) * h
    return jnp.einsum("ecf,efd->ecd", h, experts["wo"].astype(xe.dtype),
                      preferred_element_type=jnp.float32).astype(xe.dtype)


def moe_gspmd(x, p, cfg: ModelConfig):
    """x: (b, s, d) -> (b, s, d), aux_loss.  Plain-jit MoE (GSPMD baseline)."""
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    gates, idx, aux = router_topk(x2d, p["router"], cfg)
    cap = capacity(t, cfg)
    tok, e_sorted, slot, keep, order = build_dispatch(idx, t, cap, cfg)

    slot_c = jnp.where(keep, slot, 0)
    # scatter tokens into (E, C, d) expert buffers; dropped tokens masked out
    buf = jnp.zeros((cfg.num_experts, cap, d), x.dtype)
    rows = jnp.where(keep[:, None], x2d[tok], 0).astype(x.dtype)
    buf = buf.at[e_sorted, slot_c].add(rows)

    ye = expert_ffn(buf, p["experts"], cfg)

    # gather expert outputs back, weighted by gate prob
    g_sorted = gates.reshape(-1)[order]
    out_rows = ye[e_sorted, slot_c] * jnp.where(keep, g_sorted, 0.0)[:, None].astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[tok].add(out_rows)

    if cfg.num_shared_experts > 0:
        out = out + _shared(x2d, p["shared"], cfg)
    return out.reshape(b, s, d), aux


def _shared(x2d, shared, cfg: ModelConfig):
    act = jax.nn.silu if cfg.mlp_activation == "silu" else jax.nn.gelu
    h = dense(x2d, shared["wi"])
    h = act(dense(x2d, shared["wg"])) * h
    return dense(h, shared["wo"])


def moe_block(x, p, cfg: ModelConfig, mesh=None):
    """Dispatch: GSPMD baseline, or shard_map EP/TP (cfg.moe_impl == "ep").

    EP (all-to-all over `model`) when num_experts divides the model axis;
    TP (d_ff-sharded experts + psum) otherwise — e.g. Mixtral's 8 experts
    on a 16-wide axis.
    """
    if cfg.moe_impl == "ep":
        if mesh is None:
            from repro.parallel.ctx import get_ctx

            ctx = get_ctx()
            mesh = ctx.mesh if ctx is not None else None
        if mesh is not None and "model" in mesh.axis_names:
            from repro.parallel.moe_ep import moe_ep, moe_tp

            n_model = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
            if cfg.num_experts % n_model == 0:
                return moe_ep(x, p, cfg, mesh)
            if cfg.d_ff % n_model == 0:
                return moe_tp(x, p, cfg, mesh)
    return moe_gspmd(x, p, cfg)
