"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time-mix with
data-dependent per-channel decay + squared-ReLU channel-mix.

Semantics (per head, key/value dim N, state S in R^{NxN}):
    o_t[j] = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j])
    S_t    = diag(w_t) S_{t-1} + k_t (x) v_t
with w_t = exp(-exp(d_t)) in (0,1), d_t data-dependent (LoRA on the shifted
input).  Three execution forms, all matching the same oracle:

  * ``wkv_step``     — O(1) decode step (serve path).
  * ``wkv_scan``     — per-token lax.scan (oracle / small seq).
  * ``wkv_chunked``  — chunk-parallel (O(L^2 N + L N^2) per chunk) — the
    XLA analogue of the Pallas kernel ``repro.kernels.rwkv6_wkv``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense


# ---------------------------------------------------------------------------
# WKV core
# ---------------------------------------------------------------------------

def wkv_step(r, k, v, w, u, state):
    """One token.  r,k,v,w: (b, h, n); u: (h, n); state: (b, h, n, n)."""
    rkv = jnp.einsum("bhi,bhi,bhj->bhj", r, u[None] * k, v)
    o = jnp.einsum("bhi,bhij->bhj", r, state) + rkv
    state = w[..., None] * state + jnp.einsum("bhi,bhj->bhij", k, v)
    return o, state


def wkv_scan(r, k, v, w, u, state):
    """Sequence oracle.  r,k,v,w: (b, s, h, n) fp32. Returns (o, state)."""

    def body(s, inp):
        rt, kt, vt, wt = inp
        o, s = wkv_step(rt, kt, vt, wt, u, s)
        return s, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, o = jax.lax.scan(body, state, xs)
    return jnp.moveaxis(o, 0, 1), state


def _wkv_one_chunk(r, k, v, logw, u, state):
    """r,k,v,logw: (b, L, h, n) fp32; state (b,h,n,n). Chunk-parallel form."""
    L = r.shape[1]
    # P[t] = cumulative log-decay through token t;  Q[t] = through t-1.
    P = jnp.cumsum(logw, axis=1)                    # (b, L, h, n)
    Q = P - logw
    # inter-chunk: o_inter[t] = (r_t * exp(Q_t)) . S0
    r_dec = r * jnp.exp(Q)
    o = jnp.einsum("blhi,bhij->blhj", r_dec, state)
    # intra-chunk: A[t,i] = sum_c r_t[c] exp(Q_t[c]-P_i[c]) k_i[c],  i < t
    diff = Q[:, :, None] - P[:, None, :, :, :]      # (b, t, i, h, n)
    diff = jnp.where(jnp.tril(jnp.ones((L, L), bool), -1)[None, :, :, None, None],
                     diff, -jnp.inf)
    A = jnp.einsum("blhi,blmhi->blmh", r, jnp.exp(diff) * k[:, None])
    # wait: diff is (b, t, i, h, n); k broadcast over t -> k[:, None] is (b,1,i,h,n)
    o = o + jnp.einsum("blmh,bmhj->blhj", A, v)
    # current-token bonus
    o = o + jnp.einsum("blhi,blhi,blhj->blhj", r, u[None, None] * k, v)
    # state update: S_L = diag(exp(P_L)) S0 + sum_i diag(exp(P_L - P_i)) k_i v_i
    decay_all = jnp.exp(P[:, -1])                   # (b, h, n)
    carry_k = k * jnp.exp(P[:, -1:, :, :] - P)      # (b, L, h, n)
    state = decay_all[..., None] * state + jnp.einsum(
        "blhi,blhj->bhij", carry_k, v)
    return o, state


def wkv_chunked(r, k, v, logw, u, state, chunk: int, unroll: bool = False):
    """r,k,v,logw: (b, s, h, n) fp32.  Scan (or unroll) over chunks."""
    b, s, h, n = r.shape
    if s % chunk or s <= chunk:
        return wkv_scan(r, k, v, jnp.exp(logw), u, state)
    nc = s // chunk
    rs, ks, vs, ws = (
        t.reshape(b, nc, chunk, h, n).transpose(1, 0, 2, 3, 4)
        for t in (r, k, v, logw)
    )
    if unroll:
        outs = []
        for i in range(nc):
            o, state = _wkv_one_chunk(rs[i], ks[i], vs[i], ws[i], u, state)
            outs.append(o)
        o = jnp.stack(outs)
    else:
        def body(st, inp):
            ri, ki, vi, wi = inp
            o, st = _wkv_one_chunk(ri, ki, vi, wi, u, st)
            return st, o

        state, o = jax.lax.scan(body, state, (rs, ks, vs, ws))
    return o.transpose(1, 0, 2, 3, 4).reshape(b, s, h, n), state


# ---------------------------------------------------------------------------
# Block layers
# ---------------------------------------------------------------------------

def _token_shift(x, last):
    """last: (b, d) previous token (zeros at t=0). Returns shifted x."""
    prev = jnp.concatenate([last[:, None], x[:, :-1]], axis=1)
    return prev


def _group_norm(x, w, heads, eps=1e-5):
    """Per-head normalization. x: (b, s, d)."""
    b, s, d = x.shape
    xh = x.reshape(b, s, heads, d // heads).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xh - mu), axis=-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b, s, d) * w.astype(jnp.float32)).astype(x.dtype)


def time_mix(x, p, cfg: ModelConfig, state=None, chunk: int = 64,
             unroll: bool = False):
    """RWKV6 attention replacement. x: (b, s, d).

    state: None or dict(last (b,d), s (b,h,n,n)).  Returns (y, new_state).
    """
    b, s, d = x.shape
    h, n = cfg.rwkv_heads, cfg.rwkv_head_dim
    last = state["last"] if state is not None else jnp.zeros((b, d), x.dtype)
    prev = _token_shift(x, last)
    delta = prev - x
    mix = p["mix"].astype(x.dtype)  # (5, d) for r, k, v, w, g
    xr, xk, xv, xw, xg = (x + mix[i] * delta for i in range(5))

    r = dense(xr, p["wr"]).reshape(b, s, h, n).astype(jnp.float32)
    k = dense(xk, p["wk"]).reshape(b, s, h, n).astype(jnp.float32)
    v = dense(xv, p["wv"]).reshape(b, s, h, n).astype(jnp.float32)
    g = jax.nn.silu(dense(xg, p["wg"]))

    dlo = jnp.einsum("bsd,dk->bsk", jnp.tanh(xw.astype(jnp.float32)),
                     p["decay_a"].astype(jnp.float32))
    dd = p["decay_base"].astype(jnp.float32) + jnp.einsum(
        "bsk,kd->bsd", dlo, p["decay_b"].astype(jnp.float32))
    logw = -jnp.exp(jnp.clip(dd, -20.0, 10.0)).reshape(b, s, h, n)

    u = p["bonus"].astype(jnp.float32)
    s0 = (state["s"] if state is not None
          else jnp.zeros((b, h, n, n), jnp.float32))
    if s == 1 and state is not None:
        o, s1 = wkv_step(r[:, 0], k[:, 0], v[:, 0], jnp.exp(logw)[:, 0], u, s0)
        o = o[:, None]
    else:
        o, s1 = wkv_chunked(r, k, v, logw, u, s0, chunk, unroll)

    o = _group_norm(o.reshape(b, s, d).astype(x.dtype), p["gn"], h)
    y = dense(o * g, p["wo"])
    new_state = {"last": x[:, -1].astype(x.dtype), "s": s1}
    return y, new_state


def channel_mix(x, p, cfg: ModelConfig, state=None):
    """Squared-ReLU channel mix. state: dict(last (b,d)) for decode."""
    b, s, d = x.shape
    last = state["last"] if state is not None else jnp.zeros((b, d), x.dtype)
    prev = _token_shift(x, last)
    delta = prev - x
    mix = p["mix"].astype(x.dtype)
    xk = x + mix[0] * delta
    xr = x + mix[1] * delta
    kk = jnp.square(jax.nn.relu(dense(xk, p["wk"])))
    y = jax.nn.sigmoid(dense(xr, p["wr"])) * dense(kk, p["wv"])
    return y, {"last": x[:, -1].astype(x.dtype)}


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype):
    h, n = cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "tm": {"last": jnp.zeros((batch, cfg.d_model), dtype),
               "s": jnp.zeros((batch, h, n, n), jnp.float32)},
        "cm": {"last": jnp.zeros((batch, cfg.d_model), dtype)},
    }
