"""Decoder-only LM engine: training loss, prefill, and KV-cache decode for
the dense / MoE / hybrid (RG-LRU) / SSM (RWKV6) / VLM-backbone families.

Layer stacks are ``lax.scan``-ed over stacked parameters when homogeneous
(cfg.scan_layers) and unrolled otherwise (hybrid pattern, first-k-dense, and
cost-reference compiles).  Activation sharding constraints come from the
ambient :mod:`repro.parallel.ctx`.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import rglru, rwkv
from repro.models.attention import decode_self_attention, self_attention
from repro.models.config import ModelConfig
from repro.models.layers import (dense, embed_tokens, lm_logits, mlp, norm,
                                 softmax_xent)
from repro.models.moe import moe_block
from repro.parallel.ctx import shard_activation

PyTree = Any


# ---------------------------------------------------------------------------
# Blocks (training / prefill)
# ---------------------------------------------------------------------------

def decoder_block(x, bp, cfg: ModelConfig, *, moe: bool, dense_ffn_p=None,
                  collect_kv: bool = False):
    """Pre-norm decoder block. Returns (x, aux_loss, (k, v) | None)."""
    x = shard_activation(x, "act")
    h = norm(x, bp, "ln1", cfg)
    attn_out, kv = self_attention(h, bp["attn"], cfg,
                                  use_rope=cfg.family != "encdec")
    x = x + attn_out
    h = norm(x, bp, "ln2", cfg)
    aux = jnp.zeros((), jnp.float32)
    if moe:
        ff, aux = moe_block(h, bp["moe"], cfg)
    else:
        ff = mlp(h, dense_ffn_p or bp["mlp"], cfg)
    x = x + ff
    return x, aux, (kv if collect_kv else None)


def hybrid_block(x, bp, cfg: ModelConfig, layer_idx: int, state=None,
                 collect_state: bool = False):
    """RecurrentGemma block: RG-LRU or local attention + GeGLU MLP."""
    x = shard_activation(x, "act")
    h = norm(x, bp, "ln1", cfg)
    new_state = None
    if "attn" in bp:
        out, kv = self_attention(h, bp["attn"], cfg,
                                 window=cfg.attention_window)
        if collect_state:
            w = min(cfg.attention_window or x.shape[1], x.shape[1])
            new_state = {"k": kv[0][:, -w:], "v": kv[1][:, -w:]}
    else:
        out, new_state = rglru.recurrent_block(h, bp["rec"], cfg, state)
        if not collect_state:
            new_state = None
    x = x + out
    h = norm(x, bp, "ln2", cfg)
    x = x + mlp(h, bp["mlp"], cfg)
    return x, new_state


def rwkv_block(x, bp, cfg: ModelConfig, state=None, collect_state=False,
               unroll=False):
    from repro.models.layers import rmsnorm

    x = shard_activation(x, "act")
    h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
    tm_out, tm_state = rwkv.time_mix(
        h, bp["tm"], cfg, state["tm"] if state else None,
        unroll=unroll or cfg.unroll_loops)
    x = x + tm_out
    h = rmsnorm(x, bp["ln2"], cfg.norm_eps)
    cm_out, cm_state = rwkv.channel_mix(h, bp["cm"], cfg,
                                        state["cm"] if state else None)
    x = x + cm_out
    return x, ({"tm": tm_state, "cm": cm_state} if collect_state else None)


# ---------------------------------------------------------------------------
# Stack runner
# ---------------------------------------------------------------------------

def _tree_slice(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _tree_slice_dyn(tree, i):
    """Dynamic (traced-index) slice of a stacked param tree."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False), tree)


def run_stack(x, params, cfg: ModelConfig, collect_caches: bool = False):
    """Run the full block stack. Returns (hidden, aux_loss, caches)."""
    caches: Dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        layer_states = []
        for i in range(cfg.num_layers):
            bp = params["layers"][str(i)]
            block = functools.partial(hybrid_block, cfg=cfg, layer_idx=i,
                                      collect_state=collect_caches)
            if cfg.remat:
                block = jax.checkpoint(block)
            x, st = block(x, bp)
            layer_states.append(st)
        if collect_caches:
            caches["layers"] = layer_states
        return x, aux_total, caches

    if cfg.family == "ssm":
        def body(carry, bp):
            h, aux = carry
            h, st = rwkv_block(h, bp, cfg, collect_state=collect_caches)
            return (h, aux), st

        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            (x, aux_total), states = jax.lax.scan(
                body, (x, aux_total), params["blocks"])
        else:
            states = []
            for i in range(cfg.num_layers):
                (x, aux_total), st = body((x, aux_total),
                                          _tree_slice(params["blocks"], i))
                states.append(st)
            if collect_caches and states:
                states = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        if collect_caches:
            caches["blocks"] = states
        return x, aux_total, caches

    # dense / moe / vlm
    for i in range(cfg.first_k_dense):
        bp = params["dense_layers"][str(i)]
        block = functools.partial(decoder_block, cfg=cfg, moe=False,
                                  collect_kv=collect_caches)
        if cfg.remat:
            block = jax.checkpoint(block)
        x, _, kv = block(x, bp)
        if collect_caches:
            caches.setdefault("dense_layers", []).append(kv)

    is_moe = cfg.num_experts > 0

    def body(carry, bp):
        h, aux = carry
        h, a, kv = decoder_block(h, bp, cfg, moe=is_moe,
                                 collect_kv=collect_caches)
        return (h, aux + a), kv

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        (x, aux_total), kvs = jax.lax.scan(body, (x, aux_total),
                                           params["blocks"])
    else:
        kvs = []
        n = cfg.num_layers - cfg.first_k_dense
        for i in range(n):
            (x, aux_total), kv = body((x, aux_total),
                                      _tree_slice(params["blocks"], i))
            kvs.append(kv)
        if collect_caches and kvs and kvs[0] is not None:
            kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
    if collect_caches:
        caches["blocks"] = kvs
    return x, aux_total, caches


# ---------------------------------------------------------------------------
# Embedding front-ends
# ---------------------------------------------------------------------------

def embed_inputs(params, batch, cfg: ModelConfig):
    """Token (+ patch) embedding. Returns (x, label_offset)."""
    tokens = shard_activation(batch["tokens"], "tokens")
    x = embed_tokens(tokens, params["embed"]["tok"], cfg.compute_dtype)
    x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype) \
        if cfg.family == "hybrid" else x
    if cfg.family == "vlm":
        patches = batch["patches"].astype(cfg.compute_dtype)
        x = jnp.concatenate([patches, x], axis=1)
        return shard_activation(x, "act"), patches.shape[1]
    return shard_activation(x, "act"), 0


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def loss_fn(params, batch, cfg: ModelConfig):
    """Causal LM loss. batch: tokens (b, s) [+ patches (b, p, d) for vlm]."""
    x, patch_len = embed_inputs(params, batch, cfg)
    x, aux, _ = run_stack(x, params, cfg)
    x = norm(x, params, "final_norm", cfg)
    # predict tokens[1:] from positions [patch_len : -1] of the stream
    h = x[:, patch_len:-1] if patch_len else x[:, :-1]
    labels = batch["tokens"][:, 1:]
    if cfg.loss_chunk and h.shape[1] % cfg.loss_chunk == 0 \
            and h.shape[1] > cfg.loss_chunk:
        loss = _chunked_xent(h, labels, params, cfg)
    else:
        logits = lm_logits(h, params, cfg)
        logits = shard_activation(logits, "logits")
        loss = softmax_xent(logits, labels)
    metrics = {"xent": loss, "aux": aux}
    if cfg.num_experts > 0:
        loss = loss + 0.01 * aux
    return loss, metrics


def ring_place(kv, seq_end: int, s_slots: int, seq_axis: int):
    """Arrange kv entries so absolute position p lands in slot p % S.

    ``kv`` holds consecutive positions ending at ``seq_end - 1`` along
    ``seq_axis``.  The decode step writes the token at position `pos` into
    slot ``pos % S`` — this placement makes prefill and decode agree, and
    makes the overwritten slot always the oldest position (windowed caches).
    """
    n = kv.shape[seq_axis]
    m = min(n, s_slots)
    sl = [slice(None)] * kv.ndim
    sl[seq_axis] = slice(n - m, n)
    part = kv[tuple(sl)]
    if m < s_slots:
        pad = [(0, 0)] * kv.ndim
        pad[seq_axis] = (0, s_slots - m)
        part = jnp.pad(part, pad)
    shift = (seq_end - m) % s_slots
    if shift:
        part = jnp.roll(part, shift, axis=seq_axis)
    return part


def _chunked_xent(h, labels, params, cfg: ModelConfig):
    """Cross-entropy over seq chunks: the (b, chunk, vocab) logits tile is
    the only live logits buffer (memory-term lever; see EXPERIMENTS §Perf)."""
    b, s, d = h.shape
    c = cfg.loss_chunk
    nc = s // c
    hc = h.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)

    def body(carry, inp):
        hi, li = inp
        logits = lm_logits(hi, params, cfg)
        logits = shard_activation(logits, "logits")
        return carry + softmax_xent(logits, li), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / nc


def prefill(params, batch, cfg: ModelConfig, max_len: int = 0):
    """Forward over a prompt; returns (last-token logits, decode cache).

    ``max_len`` sizes the decode cache (prompt + new tokens); defaults to
    prompt length + 64.
    """
    x, patch_len = embed_inputs(params, batch, cfg)
    seq = x.shape[1]
    max_len = max_len or seq + 64
    x, _, caches = run_stack(x, params, cfg, collect_caches=True)
    x = norm(x, params, "final_norm", cfg)
    logits = lm_logits(x[:, -1:], params, cfg)[:, 0]
    cache = _caches_to_decode_cache(caches, cfg, seq, max_len, x.shape[0])
    return logits, cache


def _caches_to_decode_cache(caches, cfg: ModelConfig, seq: int, max_len: int,
                            batch: int):
    """Convert prefill-collected kv/state into the decode cache layout.

    The cache carries a per-slot position vector ``pos`` of shape (batch,)
    — after a shared-prompt prefill all rows start equal, but decode may
    advance them independently (the batched serve executor does).
    """
    window = cfg.attention_window or max_len
    s_slots = min(window, max_len)

    def trim(kv, seq_axis):
        k, v = kv
        return {
            "k": shard_activation(
                ring_place(k.astype(cfg.compute_dtype), seq, s_slots, seq_axis),
                "cache" if seq_axis == 1 else "cache"),
            "v": shard_activation(
                ring_place(v.astype(cfg.compute_dtype), seq, s_slots, seq_axis),
                "cache"),
        }

    out: Dict[str, Any] = {"pos": jnp.full((batch,), seq, jnp.int32)}
    if cfg.family == "hybrid":
        w = min(cfg.attention_window, max_len)
        layers = {}
        for i, st in enumerate(caches["layers"]):
            if "h" in st:      # recurrent state passes through unchanged
                layers[str(i)] = st
            else:              # hybrid_block already trimmed toward window
                layers[str(i)] = {
                    "k": ring_place(st["k"].astype(cfg.compute_dtype), seq, w, 1),
                    "v": ring_place(st["v"].astype(cfg.compute_dtype), seq, w, 1),
                }
        out["layers"] = layers
        return out
    if cfg.family == "ssm":
        out["blocks"] = caches["blocks"]
        return out
    if "dense_layers" in caches:
        out["dense_layers"] = {
            str(i): trim(kv, 1) for i, kv in enumerate(caches["dense_layers"])}
    # stacked kv from scan: (L, b, s, hkv, hd) — seq axis 2
    k_st, v_st = caches["blocks"]
    out["blocks"] = {
        "k": ring_place(k_st.astype(cfg.compute_dtype), seq, s_slots, 2),
        "v": ring_place(v_st.astype(cfg.compute_dtype), seq, s_slots, 2),
    }
    return out


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               abstract: bool = False):
    """Decode cache pytree (or ShapeDtypeStructs when abstract=True)."""
    window = cfg.attention_window or seq_len
    s_slots = min(window, seq_len)
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    dt = cfg.compute_dtype

    def arr(shape, dtype):
        return (jax.ShapeDtypeStruct(shape, dtype) if abstract
                else jnp.zeros(shape, dtype))

    cache: Dict[str, Any] = {"pos": arr((batch,), jnp.int32)}
    if cfg.family == "hybrid":
        layers = {}
        for i in range(cfg.num_layers):
            if cfg.is_attention_layer(i):
                w = min(cfg.attention_window, seq_len)
                layers[str(i)] = {"k": arr((batch, w, hkv, hd), dt),
                                  "v": arr((batch, w, hkv, hd), dt)}
            else:
                layers[str(i)] = {
                    "conv": arr((batch, cfg.conv_width - 1, cfg.lru_width), dt),
                    "h": arr((batch, cfg.lru_width), jnp.float32),
                }
        cache["layers"] = layers
        return cache
    if cfg.family == "ssm":
        h, n = cfg.rwkv_heads, cfg.rwkv_head_dim
        L = cfg.num_layers
        cache["blocks"] = {
            "tm": {"last": arr((L, batch, cfg.d_model), dt),
                   "s": arr((L, batch, h, n, n), jnp.float32)},
            "cm": {"last": arr((L, batch, cfg.d_model), dt)},
        }
        return cache
    if cfg.family == "encdec":
        from repro.models import whisper

        return whisper.init_cache(cfg, batch, seq_len, abstract)
    n_scanned = cfg.num_layers - cfg.first_k_dense
    for i in range(cfg.first_k_dense):
        cache.setdefault("dense_layers", {})[str(i)] = {
            "k": arr((batch, s_slots, hkv, hd), dt),
            "v": arr((batch, s_slots, hkv, hd), dt),
        }
    if cfg.decode_unroll:
        cache["layers"] = {
            str(i): {"k": arr((batch, s_slots, hkv, hd), dt),
                     "v": arr((batch, s_slots, hkv, hd), dt)}
            for i in range(n_scanned)
        }
        return cache
    cache["blocks"] = {"k": arr((n_scanned, batch, s_slots, hkv, hd), dt),
                       "v": arr((n_scanned, batch, s_slots, hkv, hd), dt)}
    return cache


# ---------------------------------------------------------------------------
# Paged decode (block-table KV storage; see repro.kernels.paged_attention)
# ---------------------------------------------------------------------------

def paged_kv_shape(cfg: ModelConfig, n_pages: int, block_tokens: int):
    """Page-pool tensor shape for one replica: every layer's KV lives in
    one stacked pool so a single block table addresses all layers."""
    return (cfg.num_layers, cfg.num_kv_heads, n_pages, block_tokens,
            cfg.head_dim)


def _full_stack_kv(cache, cfg: ModelConfig):
    """(L, b, S, hkv, hd) stacked KV from a dense/moe decode cache.

    Valid only for un-windowed caches (S == max_len), where ring_place is
    the identity for seq <= S and slot index == absolute position.
    """
    parts_k, parts_v = [], []
    for i in range(cfg.first_k_dense):
        st = cache["dense_layers"][str(i)]
        parts_k.append(st["k"][None])
        parts_v.append(st["v"][None])
    if "layers" in cache:                       # decode_unroll layout
        for i in range(cfg.num_layers - cfg.first_k_dense):
            st = cache["layers"][str(i)]
            parts_k.append(st["k"][None])
            parts_v.append(st["v"][None])
    else:
        parts_k.append(cache["blocks"]["k"])
        parts_v.append(cache["blocks"]["v"])
    return (jnp.concatenate(parts_k, 0) if len(parts_k) > 1 else parts_k[0],
            jnp.concatenate(parts_v, 0) if len(parts_v) > 1 else parts_v[0])


def scatter_prefill_pages(cache, cfg: ModelConfig, k_pages, v_pages,
                          page_ids, offs):
    """Scatter a batch-1 prefill cache into the paged KV pool.

    ``page_ids``/``offs`` are (s,) int32 for absolute positions 0..s-1 —
    position p goes to ``(page_ids[p], offs[p])`` per the block-table ABI.
    Returns the updated (k_pages, v_pages), shape
    ``paged_kv_shape(cfg, n_pages, block_tokens)``.
    """
    k_st, v_st = _full_stack_kv(cache, cfg)     # (L, 1, S, hkv, hd)
    s = page_ids.shape[0]
    kv_k = k_st[:, 0, :s].transpose(0, 2, 1, 3)  # (L, hkv, s, hd)
    kv_v = v_st[:, 0, :s].transpose(0, 2, 1, 3)
    k_pages = k_pages.at[:, :, page_ids, offs].set(kv_k.astype(k_pages.dtype))
    v_pages = v_pages.at[:, :, page_ids, offs].set(kv_v.astype(v_pages.dtype))
    return k_pages, v_pages


def paged_decode_step(params, token, lengths, k_pages, v_pages, block_tables,
                      cfg: ModelConfig, *, attn_impl: str = "auto",
                      interpret: bool = False):
    """One batched decode step over paged KV storage.

    token: (b,) int32 (last sampled token per row); lengths: (b,) int32
    valid positions per row *including* the token written this step
    (the engine's ``append_token`` runs first), so the new KV is written
    at absolute position ``lengths - 1`` and attention spans ``lengths``
    positions.  ``lengths == 0`` marks an inactive batch row: its writes
    land in whatever (null) page its all-null block-table row names, and
    its logits are garbage the caller must mask.  Fixed shapes in, fixed
    shapes out — admission/detach never recompiles.

    Returns (logits (b, V), k_pages, v_pages).
    """
    from repro.kernels.paged_attention.ops import paged_attention_decode

    b = token.shape[0]
    btok = k_pages.shape[3]
    write_pos = jnp.maximum(lengths - 1, 0)
    page_ids = jnp.take_along_axis(
        block_tables, (write_pos // btok)[:, None], axis=1)[:, 0]
    offs = write_pos % btok
    positions = write_pos[:, None].astype(jnp.int32)
    window = cfg.attention_window or 0
    use_rope = cfg.family != "encdec"

    x = embed_tokens(token[:, None], params["embed"]["tok"], cfg.compute_dtype)

    def attn_layer(h, bp, li, kp, vp):
        """li: page-pool layer index (dense layers first, then blocks)."""
        from repro.models.attention import merge_heads_out, project_qkv

        h = shard_activation(h, "act")
        hn = norm(h, bp, "ln1", cfg)
        q, k, v = project_qkv(hn, bp["attn"], cfg, positions, use_rope)
        kpi = jax.lax.dynamic_index_in_dim(kp, li, 0, keepdims=False)
        vpi = jax.lax.dynamic_index_in_dim(vp, li, 0, keepdims=False)
        # (b, 1, hkv, hd) -> (hkv, b, hd): row r writes (page_ids[r], offs[r])
        kpi = kpi.at[:, page_ids, offs].set(
            k[:, 0].transpose(1, 0, 2).astype(kpi.dtype))
        vpi = vpi.at[:, page_ids, offs].set(
            v[:, 0].transpose(1, 0, 2).astype(vpi.dtype))
        kp = jax.lax.dynamic_update_index_in_dim(kp, kpi, li, 0)
        vp = jax.lax.dynamic_update_index_in_dim(vp, vpi, li, 0)
        o = paged_attention_decode(q[:, 0], kpi, vpi, block_tables, lengths,
                                   window=window, impl=attn_impl,
                                   interpret=interpret)
        return h + merge_heads_out(o[:, None], bp["attn"]), kp, vp

    for i in range(cfg.first_k_dense):
        bp = params["dense_layers"][str(i)]
        x, k_pages, v_pages = attn_layer(x, bp, jnp.asarray(i),
                                         k_pages, v_pages)
        hn = norm(x, bp, "ln2", cfg)
        x = x + mlp(hn, bp["mlp"], cfg)

    is_moe = cfg.num_experts > 0
    n_layers = cfg.num_layers - cfg.first_k_dense
    base = cfg.first_k_dense

    def body(i, carry):
        h, kp, vp = carry
        bp = _tree_slice_dyn(params["blocks"], i)
        h, kp, vp = attn_layer(h, bp, base + i, kp, vp)
        hn = norm(h, bp, "ln2", cfg)
        if is_moe:
            ff, _ = moe_block(hn, bp["moe"], cfg)
        else:
            ff = mlp(hn, bp["mlp"], cfg)
        return h + ff, kp, vp

    if cfg.unroll_loops:
        carry = (x, k_pages, v_pages)
        for i in range(n_layers):
            carry = body(jnp.asarray(i), carry)
        x, k_pages, v_pages = carry
    else:
        x, k_pages, v_pages = jax.lax.fori_loop(
            0, n_layers, body, (x, k_pages, v_pages))

    x = norm(x, params, "final_norm", cfg)
    logits = lm_logits(x[:, -1], params, cfg)
    return logits, k_pages, v_pages


def decode_step(params, token, cache, cfg: ModelConfig):
    """One decode step. token: (b,) int32. Returns (logits (b, V), cache)."""
    x = embed_tokens(token[:, None], params["embed"]["tok"], cfg.compute_dtype)
    if cfg.family == "hybrid":
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    # pos: scalar (legacy shared position) or (b,) per-slot vector
    pos = jnp.asarray(cache["pos"])
    new_cache: Dict[str, Any] = {"pos": pos + 1}

    if cfg.family == "hybrid":
        new_layers = {}
        for i in range(cfg.num_layers):
            bp = params["layers"][str(i)]
            st = cache["layers"][str(i)]
            x = shard_activation(x, "act")
            h = norm(x, bp, "ln1", cfg)
            if "attn" in bp:
                lc = dict(st)
                lc["pos"] = pos
                out, lc = decode_self_attention(h, bp["attn"], cfg, lc)
                new_layers[str(i)] = {"k": lc["k"], "v": lc["v"]}
            else:
                out, new_st = rglru.recurrent_block(h, bp["rec"], cfg, st)
                new_layers[str(i)] = new_st
            x = x + out
            h = norm(x, bp, "ln2", cfg)
            x = x + mlp(h, bp["mlp"], cfg)
        new_cache["layers"] = new_layers

    elif cfg.family == "ssm":
        def body(h, inp):
            bp, st = inp
            h, new_st = rwkv_block(h, bp, cfg, state=st, collect_state=True)
            return h, new_st

        if cfg.unroll_loops:
            sts = []
            for i in range(cfg.num_layers):
                x, st = body(x, (_tree_slice(params["blocks"], i),
                                 _tree_slice(cache["blocks"], i)))
                sts.append(st)
            states = jax.tree.map(lambda *xs: jnp.stack(xs), *sts)
        else:
            x, states = jax.lax.scan(body, x,
                                     (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = states

    else:
        for i in range(cfg.first_k_dense):
            bp = params["dense_layers"][str(i)]
            st = dict(cache["dense_layers"][str(i)])
            st["pos"] = pos
            x = shard_activation(x, "act")
            h = norm(x, bp, "ln1", cfg)
            out, st = decode_self_attention(h, bp["attn"], cfg, st)
            x = x + out
            h = norm(x, bp, "ln2", cfg)
            x = x + mlp(h, bp["mlp"], cfg)
            new_cache.setdefault("dense_layers", {})[str(i)] = {
                "k": st["k"], "v": st["v"]}

        is_moe = cfg.num_experts > 0
        if cfg.decode_unroll:
            # unrolled layers + per-leaf caches: each donated (k, v) pair
            # aliases straight through to the output (no while-loop carry).
            new_layers = {}
            n = cfg.num_layers - cfg.first_k_dense
            for i in range(n):
                bp = _tree_slice(params["blocks"], i)
                st = dict(cache["layers"][str(i)])
                st["pos"] = pos
                x = shard_activation(x, "act")
                h = norm(x, bp, "ln1", cfg)
                out, st = decode_self_attention(h, bp["attn"], cfg, st)
                x = x + out
                h = norm(x, bp, "ln2", cfg)
                if is_moe:
                    ff, _ = moe_block(h, bp["moe"], cfg)
                else:
                    ff = mlp(h, bp["mlp"], cfg)
                x = x + ff
                new_layers[str(i)] = {"k": st["k"], "v": st["v"]}
            new_cache["layers"] = new_layers
            x = norm(x, params, "final_norm", cfg)
            logits = lm_logits(x[:, -1], params, cfg)
            return logits, new_cache
        ks0 = cache["blocks"]["k"]
        vs0 = cache["blocks"]["v"]
        b = x.shape[0]
        s_slots = ks0.shape[2]
        slot = pos % s_slots
        n_valid = jnp.minimum(pos + 1, s_slots)
        n_layers = ks0.shape[0]
        vec_pos = pos.ndim > 0
        if vec_pos:
            # (b, S, 1, 1) one-hot: row b writes at its own slot pos[b] % S
            write_oh = (jnp.arange(s_slots)[None, :]
                        == slot[:, None])[:, :, None, None]

        def body(i, carry):
            # fori_loop + in-place dynamic_update_slice keeps the (donated)
            # cache stack aliased input->output — a lax.scan over ys would
            # allocate a second full cache (OVER-HBM at 32k depth; §Perf).
            h, ks, vs = carry
            bp = _tree_slice_dyn(params["blocks"], i)
            h = shard_activation(h, "act")
            hn = norm(h, bp, "ln1", cfg)
            from repro.models.attention import (decode_attention,
                                                merge_heads_out, project_qkv)

            positions = (pos[:, None].astype(jnp.int32) if vec_pos
                         else jnp.full((b, 1), pos, jnp.int32))
            q, k, v = project_qkv(hn, bp["attn"], cfg, positions,
                                  use_rope=cfg.family != "encdec")
            if vec_pos:
                k_cache = jax.lax.dynamic_index_in_dim(ks, i, 0,
                                                       keepdims=False)
                v_cache = jax.lax.dynamic_index_in_dim(vs, i, 0,
                                                       keepdims=False)
                k_cache = jnp.where(write_oh, k.astype(ks.dtype), k_cache)
                v_cache = jnp.where(write_oh, v.astype(vs.dtype), v_cache)
                ks = jax.lax.dynamic_update_index_in_dim(ks, k_cache, i, 0)
                vs = jax.lax.dynamic_update_index_in_dim(vs, v_cache, i, 0)
            else:
                ks = jax.lax.dynamic_update_slice(
                    ks, k.astype(ks.dtype).reshape(1, b, 1, *k.shape[2:]),
                    (i, 0, slot, 0, 0))
                vs = jax.lax.dynamic_update_slice(
                    vs, v.astype(vs.dtype).reshape(1, b, 1, *v.shape[2:]),
                    (i, 0, slot, 0, 0))
                k_cache = jax.lax.dynamic_index_in_dim(ks, i, 0,
                                                       keepdims=False)
                v_cache = jax.lax.dynamic_index_in_dim(vs, i, 0,
                                                       keepdims=False)
            o = decode_attention(q, k_cache, v_cache, n_valid)
            h = h + merge_heads_out(o, bp["attn"])
            hn = norm(h, bp, "ln2", cfg)
            if is_moe:
                ff, _ = moe_block(hn, bp["moe"], cfg)
            else:
                ff = mlp(hn, bp["mlp"], cfg)
            return h + ff, ks, vs

        if cfg.unroll_loops:   # cost-reference compiles (core.costref)
            carry = (x, ks0, vs0)
            for i in range(n_layers):
                carry = body(jnp.asarray(i), carry)
            x, ks, vs = carry
        else:
            x, ks, vs = jax.lax.fori_loop(0, n_layers, body, (x, ks0, vs0))
        new_cache["blocks"] = {"k": ks, "v": vs}

    x = norm(x, params, "final_norm", cfg)
    logits = lm_logits(x[:, -1], params, cfg)
    return logits, new_cache
