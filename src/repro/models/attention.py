"""Attention: GQA + RoPE + sliding-window, chunked (flash-style) for long
sequences, plus single-token decode against a (ring-buffer) KV cache.

The chunked path is the XLA-compileable analogue of the Pallas flash kernel
in ``repro.kernels.flash_attention`` — O(chunk x kv) live memory, lax.scan
over query blocks.  The Pallas kernel is used on real TPUs; this path is what
the dry-run lowers (identical FLOPs, so roofline terms match).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense, rope

NEG_INF = -1e30


def _grouped_scores(q, k):
    """q: (b, sq, hkv, g, hd)  k: (b, skv, hkv, hd) -> (b, hkv, g, sq, skv)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32)


def _apply_probs(p, v):
    """p: (b, hkv, g, sq, skv)  v: (b, skv, hkv, hd) -> (b, sq, hkv, g, hd)."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def _softmax(scores, mask):
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(m))
    return e / (jnp.sum(e, axis=-1, keepdims=True) + 1e-30)


def _mask(q_pos, kv_pos, causal: bool, window: int):
    """(sq, skv) boolean mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= kv_pos[None, :] > (q_pos[:, None] - window)
    return m


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              chunk: int = 0, q_offset: int = 0,
              unroll: bool = False) -> jax.Array:
    """Full-sequence attention.

    q: (b, sq, hq, hd); k, v: (b, skv, hkv, hd).  hq must be a multiple of
    hkv (GQA).  `q_offset` is the absolute position of q[0] (prefill
    continuation); kv is assumed to start at position 0.
    """
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = hd ** -0.5
    q = (q * scale).reshape(b, sq, hkv, g, hd)

    if chunk and sq > chunk and sq % chunk == 0:
        return _chunked(q, k, v, causal=causal, window=window, chunk=chunk,
                        q_offset=q_offset,
                        unroll=unroll).reshape(b, sq, hq, hd)

    q_pos = q_offset + jnp.arange(sq)
    kv_pos = jnp.arange(k.shape[1])
    mask = _mask(q_pos, kv_pos, causal, window)
    scores = _grouped_scores(q, k)
    probs = _softmax(scores, mask[None, None, None])
    return _apply_probs(probs, v).astype(q.dtype).reshape(b, sq, hq, hd)


def _chunked(q, k, v, *, causal, window, chunk, q_offset, unroll=False):
    """lax.scan over query chunks; windowed attention slices kv statically.

    q: (b, sq, hkv, g, hd) pre-scaled.  Returns (b, sq, hkv, g, hd).
    """
    b, sq, hkv, g, hd = q.shape
    skv = k.shape[1]
    nc = sq // chunk
    qc = q.reshape(b, nc, chunk, hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)

    kv_window = 0
    if window > 0:
        # Each query chunk only ever sees the last `window + chunk` kv slots.
        kv_window = min(skv, window + chunk)

    def body(_, args):
        idx, qi = args  # qi: (b, chunk, hkv, g, hd)
        start = idx * chunk + q_offset
        q_pos = start + jnp.arange(chunk)
        if kv_window:
            kv_start = jnp.clip(start + chunk - kv_window, 0, skv - kv_window)
            ki = jax.lax.dynamic_slice_in_dim(k, kv_start, kv_window, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, kv_start, kv_window, axis=1)
            kv_pos = kv_start + jnp.arange(kv_window)
        else:
            ki, vi = k, v
            kv_pos = jnp.arange(skv)
        mask = (kv_pos[None, :] <= q_pos[:, None]) if causal else \
            jnp.ones((chunk, kv_pos.shape[0]), bool)
        if window > 0:
            mask &= kv_pos[None, :] > (q_pos[:, None] - window)
        scores = _grouped_scores(qi, ki)
        probs = _softmax(scores, mask[None, None, None])
        out = _apply_probs(probs, vi).astype(qi.dtype)
        return None, out

    if unroll:
        outs = jnp.stack([body(None, (jnp.asarray(i), qc[i]))[1]
                          for i in range(nc)])
    else:
        _, outs = jax.lax.scan(body, None, (jnp.arange(nc), qc))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hkv, g, hd)


def decode_attention(q, k_cache, v_cache, n_valid) -> jax.Array:
    """One-token attention against a cache.

    q: (b, 1, hq, hd); caches: (b, S, hkv, hd) with `n_valid` filled slots.
    `n_valid` may be a scalar (shared position, legacy batch-1 decode) or a
    (b,) vector (per-slot positions, the batched serve path — rows with
    ``n_valid == 0`` attend to nothing and emit zeros through the softmax
    epsilon).  Cache slot order is irrelevant (keys stored post-RoPE), so
    ring-buffer rotation needs no unpermute.
    """
    b, _, hq, hd = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    qg = (q * hd ** -0.5).reshape(b, 1, hkv, g, hd)
    scores = _grouped_scores(qg, k_cache)            # (b, hkv, g, 1, S)
    S = k_cache.shape[1]
    n_valid = jnp.asarray(n_valid)
    if n_valid.ndim == 0:
        mask = (jnp.arange(S) < n_valid)[None, None, None, None, :]
    else:
        mask = (jnp.arange(S)[None, :] <
                n_valid[:, None])[:, None, None, None, :]
    probs = _softmax(scores, mask)
    out = _apply_probs(probs, v_cache).astype(q.dtype)
    return out.reshape(b, 1, hq, hd)


# ---------------------------------------------------------------------------
# Projection wrappers
# ---------------------------------------------------------------------------

def project_qkv(x, p, cfg: ModelConfig, positions, use_rope: bool = True):
    b, s, _ = x.shape
    q = dense(x, p["wq"], p.get("bq")).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = dense(x, p["wk"], p.get("bk")).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = dense(x, p["wv"], p.get("bv")).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def merge_heads_out(o, p):
    b, s = o.shape[:2]
    return dense(o.reshape(b, s, -1), p["wo"])


def self_attention(x, p, cfg: ModelConfig, *, positions=None, causal=True,
                   window: Optional[int] = None, use_rope=True):
    """Training / prefill self-attention over the whole sequence."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = project_qkv(x, p, cfg, positions, use_rope)
    if cfg.attn_kv_gather:
        # gather K/V across the model axis once per layer; the chunked dot
        # then runs on full-seq kv locally instead of emitting per-chunk
        # partial-sum all-reduces (SP attention; EXPERIMENTS §Perf).
        from repro.parallel.ctx import shard_activation

        k = shard_activation(k, "kv_rep")
        v = shard_activation(v, "kv_rep")
    w = cfg.attention_window if window is None else window
    o = attention(q, k, v, causal=causal, window=w, chunk=cfg.attn_chunk,
                  unroll=cfg.unroll_loops)
    return merge_heads_out(o, p), (k, v)


def cross_attention(x, p, cfg: ModelConfig, k, v):
    b, s, _ = x.shape
    q = dense(x, p["wq"], p.get("bq")).reshape(b, s, cfg.num_heads, cfg.head_dim)
    o = attention(q, k, v, causal=False, window=0, chunk=0)
    return merge_heads_out(o, p)


def decode_self_attention(x, p, cfg: ModelConfig, cache, layer_cache_idx=None,
                          use_rope=True):
    """x: (b, 1, d).  cache: dict with k/v (b, S, hkv, hd), pos (scalar int32
    shared across the batch, or a (b,) per-slot position vector).

    Writes the new kv at slot pos % S (ring buffer for windowed caches) and
    attends over min(pos + 1, S) valid slots — per row when pos is a vector
    (slot `b` writes at pos[b] % S), which is what lets a fixed-width batched
    executor decode mixed-length requests in one call.
    """
    b = x.shape[0]
    S = cache["k"].shape[1]
    pos = jnp.asarray(cache["pos"])
    if pos.ndim == 0:
        positions = jnp.full((b, 1), pos, jnp.int32)
        q, k, v = project_qkv(x, p, cfg, positions, use_rope)
        slot = pos % S
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    else:
        positions = pos[:, None].astype(jnp.int32)
        q, k, v = project_qkv(x, p, cfg, positions, use_rope)
        # one-hot masked write: row b lands at its own slot pos[b] % S
        oh = (jnp.arange(S)[None, :] == (pos % S)[:, None])[:, :, None, None]
        k_cache = jnp.where(oh, k.astype(cache["k"].dtype), cache["k"])
        v_cache = jnp.where(oh, v.astype(cache["v"].dtype), cache["v"])
    n_valid = jnp.minimum(pos + 1, S)
    o = decode_attention(q, k_cache, v_cache, n_valid)
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos}
    return merge_heads_out(o, p), new_cache
