"""Primitive layers shared by every architecture family (pure JAX)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def rmsnorm(x, w, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * w.astype(jnp.float32)).astype(dt)


def layernorm(x, w, b, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm(x, block, name: str, cfg: ModelConfig):
    if cfg.norm_type == "layernorm":
        return layernorm(x, block[name], block[f"{name}_b"], cfg.norm_eps)
    return rmsnorm(x, block[name], cfg.norm_eps)


@jax.custom_vjp
def _dense_bf16grad(x, w):
    return jnp.einsum("...d,df->...f", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _dense_bf16grad_fwd(x, w):
    return _dense_bf16grad(x, w), (x, w)


def _dense_bf16grad_bwd(res, dy):
    x, w = res
    dy = dy.astype(x.dtype)
    gx = jnp.einsum("...f,df->...d", dy, w,
                    preferred_element_type=jnp.float32).astype(x.dtype)
    # weight-grad partials in bf16: the batch/seq contraction is sharded
    # over data, so the per-device partial dot's OUTPUT dtype is what the
    # data-parallel all-reduce moves.  fp32 output would force an fp32
    # all-reduce (a cast after the reduce cannot move before it); bf16
    # output halves the dominant collective (EXPERIMENTS §Perf; MXU still
    # accumulates fp32 internally, and fp32 Adam absorbs the rounding).
    gw = jnp.einsum("...d,...f->df", x, dy,
                    preferred_element_type=w.dtype)
    return gx, gw


_dense_bf16grad.defvjp(_dense_bf16grad_fwd, _dense_bf16grad_bwd)


def dense(x, w, b=None):
    """x @ w in compute dtype with fp32 accumulation."""
    from repro.parallel.ctx import get_ctx

    ctx = get_ctx()
    if ctx is not None and getattr(ctx, "bf16_grad", False) \
            and w.ndim == 2 and w.dtype == x.dtype:
        y = _dense_bf16grad(x, w)
    else:
        y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def mlp(x, p, cfg: ModelConfig):
    """(Gated) MLP: silu/gelu — SwiGLU or GeGLU when cfg.mlp_gated."""
    act = jax.nn.silu if cfg.mlp_activation == "silu" else jax.nn.gelu
    h = dense(x, p["wi"])
    if cfg.mlp_gated:
        h = act(dense(x, p["wg"])) * h
    else:
        h = act(h)
    return dense(h, p["wo"])


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]   # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def embed_tokens(tokens, w, compute_dtype):
    return jnp.take(w, tokens, axis=0).astype(compute_dtype)


def lm_logits(x, params, cfg: ModelConfig, softcap: float = 0.0):
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].T
    else:
        w = params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    cap = softcap or cfg.logit_softcap
    if cap > 0:
        logits = cap * jnp.tanh(logits / cap)
    return logits


def softmax_xent(logits, labels):
    """Mean token cross-entropy in fp32 — works with vocab-sharded logits."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
