"""Parameter specification + initialization for every architecture family.

Every parameter is described by a :class:`ParamSpec` carrying its shape,
dtype and *logical axis names*.  The parallel layer (`repro.parallel`) maps
logical axes onto mesh axes; the dry-run builds ShapeDtypeStructs from the
same specs without allocating anything.

Parameter tree layout (nested dicts):
  embed.tok                 (vocab, d)
  embed.pos_enc             (enc_positions, d)          [whisper]
  embed.pos_dec             (max_dec_positions, d)      [whisper]
  blocks.*                  stacked homogeneous decoder blocks (leading L dim)
  dense_layers.<i>.*        unrolled leading dense layers (deepseek first_k_dense)
  layers.<i>.*              unrolled heterogeneous blocks (hybrid / recurrentgemma)
  enc_blocks.* / dec_blocks.*  whisper stacks
  final_norm                (d,)
  lm_head                   (d, vocab)                  [absent when tied]
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]          # logical axis names, same rank as shape
    dtype: Any = jnp.float32
    init: str = "fan_in"           # fan_in | normal | zeros | ones | lru_a | rwkv_decay

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# ---------------------------------------------------------------------------
# Block spec builders
# ---------------------------------------------------------------------------

def _attn_specs(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamSpec]:
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": ParamSpec((d, q), ("embed", "heads")),
        "wk": ParamSpec((d, kv), ("embed", "kv")),
        "wv": ParamSpec((d, kv), ("embed", "kv")),
        "wo": ParamSpec((q, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((q,), ("vec",), init="zeros")
        p["bk"] = ParamSpec((kv,), ("vec",), init="zeros")
        p["bv"] = ParamSpec((kv,), ("vec",), init="zeros")
    return p


def _mlp_specs(cfg: ModelConfig, d_ff: int = 0) -> Dict[str, ParamSpec]:
    d, ff = cfg.d_model, (d_ff or cfg.d_ff)
    p = {
        "wi": ParamSpec((d, ff), ("embed", "ffn")),
        "wo": ParamSpec((ff, d), ("ffn", "embed")),
    }
    if cfg.mlp_gated:
        p["wg"] = ParamSpec((d, ff), ("embed", "ffn"))
    return p


def _moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {
        "router": ParamSpec((d, e), ("embed", "experts_r")),
        "experts": {
            "wi": ParamSpec((e, d, ff), ("experts", "embed", "ffn")),
            "wg": ParamSpec((e, d, ff), ("experts", "embed", "ffn")),
            "wo": ParamSpec((e, ff, d), ("experts", "ffn", "embed")),
        },
    }
    if cfg.num_shared_experts > 0:
        sff = cfg.num_shared_experts * ff
        p["shared"] = {
            "wi": ParamSpec((d, sff), ("embed", "ffn")),
            "wg": ParamSpec((d, sff), ("embed", "ffn")),
            "wo": ParamSpec((sff, d), ("ffn", "embed")),
        }
    return p


def _rglru_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    """RecurrentGemma recurrent block: proj -> conv1d -> RG-LRU -> gated out."""
    d, w = cfg.d_model, cfg.lru_width
    return {
        "w_y": ParamSpec((d, w), ("embed", "rnn")),      # value branch
        "w_gate": ParamSpec((d, w), ("embed", "rnn")),   # multiplicative gate
        "conv_w": ParamSpec((cfg.conv_width, w), ("vec", "rnn")),
        "conv_b": ParamSpec((w,), ("vec",), init="zeros"),
        "lru_wa": ParamSpec((w, w), ("rnn_in", "rnn")),  # recurrence gate
        "lru_wx": ParamSpec((w, w), ("rnn_in", "rnn")),  # input gate
        "lru_ba": ParamSpec((w,), ("vec",), init="zeros"),
        "lru_bx": ParamSpec((w,), ("vec",), init="zeros"),
        "lru_a": ParamSpec((w,), ("vec",), init="lru_a"),  # log-decay param
        "w_out": ParamSpec((w, d), ("rnn", "embed")),
    }


def _rwkv_block_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    """RWKV6 'Finch': data-dependent-decay time mix + squared-relu channel mix."""
    d, ff = cfg.d_model, cfg.d_ff
    lora = 64
    return {
        "ln1": ParamSpec((d,), ("vec",), init="ones"),
        "ln2": ParamSpec((d,), ("vec",), init="ones"),
        "tm": {
            # token-shift interpolation weights for (r, k, v, w, g)
            "mix": ParamSpec((5, d), ("vec", "embed_v"), init="normal"),
            "wr": ParamSpec((d, d), ("embed", "rnn")),
            "wk": ParamSpec((d, d), ("embed", "rnn")),
            "wv": ParamSpec((d, d), ("embed", "rnn")),
            "wg": ParamSpec((d, d), ("embed", "rnn")),
            "wo": ParamSpec((d, d), ("rnn", "embed")),
            "decay_base": ParamSpec((d,), ("vec",), init="rwkv_decay"),
            "decay_a": ParamSpec((d, lora), ("embed", "vec"), init="normal"),
            "decay_b": ParamSpec((lora, d), ("vec", "embed_v"), init="zeros"),
            "bonus": ParamSpec((cfg.rwkv_heads, cfg.rwkv_head_dim), ("vec", "vec2"), init="normal"),
            "gn": ParamSpec((d,), ("vec",), init="ones"),
        },
        "cm": {
            "mix": ParamSpec((2, d), ("vec", "embed_v"), init="normal"),
            "wk": ParamSpec((d, ff), ("embed", "ffn")),
            "wv": ParamSpec((ff, d), ("ffn", "embed")),
            "wr": ParamSpec((d, d), ("embed", "rnn")),
        },
    }


def _decoder_block_specs(cfg: ModelConfig, moe: bool) -> Dict[str, ParamSpec]:
    p: Dict[str, Any] = {
        "ln1": ParamSpec((cfg.d_model,), ("vec",), init="ones"),
        "ln2": ParamSpec((cfg.d_model,), ("vec",), init="ones"),
        "attn": _attn_specs(cfg),
    }
    if cfg.norm_type == "layernorm":
        p["ln1_b"] = ParamSpec((cfg.d_model,), ("vec",), init="zeros")
        p["ln2_b"] = ParamSpec((cfg.d_model,), ("vec",), init="zeros")
    if moe:
        p["moe"] = _moe_specs(cfg)
    else:
        p["mlp"] = _mlp_specs(cfg)
    return p


def _hybrid_block_specs(cfg: ModelConfig, layer_idx: int) -> Dict[str, ParamSpec]:
    p: Dict[str, Any] = {
        "ln1": ParamSpec((cfg.d_model,), ("vec",), init="ones"),
        "ln2": ParamSpec((cfg.d_model,), ("vec",), init="ones"),
        "mlp": _mlp_specs(cfg),
    }
    if cfg.is_attention_layer(layer_idx):
        p["attn"] = _attn_specs(cfg)
    else:
        p["rec"] = _rglru_specs(cfg)
    return p


def _whisper_enc_block(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    return {
        "ln1": ParamSpec((d,), ("vec",), init="ones"),
        "ln1_b": ParamSpec((d,), ("vec",), init="zeros"),
        "ln2": ParamSpec((d,), ("vec",), init="ones"),
        "ln2_b": ParamSpec((d,), ("vec",), init="zeros"),
        "attn": _attn_specs(cfg),
        "mlp": _mlp_specs(cfg),
    }


def _whisper_dec_block(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    return {
        "ln1": ParamSpec((d,), ("vec",), init="ones"),
        "ln1_b": ParamSpec((d,), ("vec",), init="zeros"),
        "ln_x": ParamSpec((d,), ("vec",), init="ones"),
        "ln_x_b": ParamSpec((d,), ("vec",), init="zeros"),
        "ln2": ParamSpec((d,), ("vec",), init="ones"),
        "ln2_b": ParamSpec((d,), ("vec",), init="zeros"),
        "attn": _attn_specs(cfg),
        "xattn": _attn_specs(cfg, cross=True),
        "mlp": _mlp_specs(cfg),
    }


def _stack(tree: PyTree, n: int) -> PyTree:
    """Prepend a stacked `layers` axis of length n to every spec in tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype, s.init),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# Full-model spec trees
# ---------------------------------------------------------------------------

def spec_tree(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    tree: Dict[str, Any] = {
        "embed": {"tok": ParamSpec((cfg.vocab_size, d), ("vocab", "embed"), init="normal")},
        "final_norm": ParamSpec((d,), ("vec",), init="ones"),
    }
    if cfg.norm_type == "layernorm":
        tree["final_norm_b"] = ParamSpec((d,), ("vec",), init="zeros")
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamSpec((d, cfg.vocab_size), ("embed", "vocab"))

    if cfg.family == "encdec":
        tree["embed"]["pos_dec"] = ParamSpec((32_768, d), ("pos", "embed"), init="normal")
        tree["final_norm_enc"] = ParamSpec((d,), ("vec",), init="ones")
        tree["final_norm_enc_b"] = ParamSpec((d,), ("vec",), init="zeros")
        tree["enc_blocks"] = _stack(_whisper_enc_block(cfg), cfg.encoder_layers)
        tree["dec_blocks"] = _stack(_whisper_dec_block(cfg), cfg.num_layers)
        return _apply_param_dtype(tree, cfg)

    if cfg.family == "hybrid":
        # heterogeneous 1:2 attention:recurrent pattern -> unrolled layers
        tree["layers"] = {
            str(i): _hybrid_block_specs(cfg, i) for i in range(cfg.num_layers)
        }
        return _apply_param_dtype(tree, cfg)

    if cfg.family == "ssm":
        tree["blocks"] = _stack(_rwkv_block_specs(cfg), cfg.num_layers)
        return _apply_param_dtype(tree, cfg)

    # dense / moe / vlm decoder-only stacks
    n_scanned = cfg.num_layers - cfg.first_k_dense
    if cfg.first_k_dense > 0:
        dense_cfg = cfg
        tree["dense_layers"] = {
            str(i): {
                "ln1": ParamSpec((d,), ("vec",), init="ones"),
                "ln2": ParamSpec((d,), ("vec",), init="ones"),
                "attn": _attn_specs(cfg),
                "mlp": _mlp_specs(cfg, cfg.d_ff_dense or cfg.d_ff),
            }
            for i in range(cfg.first_k_dense)
        }
    tree["blocks"] = _stack(
        _decoder_block_specs(cfg, moe=cfg.num_experts > 0), n_scanned
    )
    return _apply_param_dtype(tree, cfg)


def _apply_param_dtype(tree, cfg: ModelConfig):
    """Matrix weights take cfg.param_dtype (bf16 serving checkpoints);
    vectors/norms stay fp32."""
    if cfg.param_dtype == jnp.float32:
        return tree
    return jax.tree.map(
        lambda s: (ParamSpec(s.shape, s.axes, cfg.param_dtype, s.init)
                   if len(s.shape) >= 2 else s),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    """Flat {dotted.name: ParamSpec} view (for counting / sharding tables)."""
    flat = {}

    def visit(prefix, node):
        if isinstance(node, ParamSpec):
            flat[prefix] = node
            return
        for k, v in node.items():
            visit(f"{prefix}.{k}" if prefix else k, v)

    visit("", spec_tree(cfg))
    return flat


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _init_leaf(key, spec: ParamSpec, cfg: ModelConfig):
    shape, dtype = spec.shape, spec.dtype
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "normal":
        return (0.02 * jax.random.normal(key, shape)).astype(dtype)
    if spec.init == "lru_a":
        # RG-LRU decay in [0.9, 0.999]:  a = sigmoid(p) ** (c) parameterised via
        # softplus-log trick; store p with a ~ U[0.9, 0.999].
        u = jax.random.uniform(key, shape, minval=0.9, maxval=0.999)
        return jnp.log(-jnp.log(u)).astype(dtype)  # a = exp(-exp(p))
    if spec.init == "rwkv_decay":
        # per-channel decay ramp as in RWKV reference inits
        d = shape[-1]
        ramp = jnp.arange(d) / max(d - 1, 1)
        return jnp.broadcast_to((-6.0 + 5.0 * ramp).astype(dtype), shape)
    # fan_in scaled
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    tree = spec_tree(cfg)
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s, cfg) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        spec_tree(cfg),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
