"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> {value branch: linear -> causal conv1d -> RG-LRU} * gate branch
         -> output projection.

RG-LRU recurrence (per channel):
    r_t = sigmoid(x_t @ W_a + b_a)                    (recurrence gate)
    i_t = sigmoid(x_t @ W_x + b_x)                    (input gate)
    log_a_t = -c * softplus_free(Lambda) * r_t        (c = 8)
    a_t = exp(log_a_t)        with Lambda parameterised so a in [0.9, 0.999]
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The sequence form runs as an associative scan (O(log seq) depth); decode
carries (conv_state, h) and is O(1) per token.  The Pallas kernel
``repro.kernels.rglru_scan`` implements the blocked VMEM version.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense

_C = 8.0


def _gates(x, p):
    r = jax.nn.sigmoid(dense(x, p["lru_wa"], p["lru_ba"]).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(x, p["lru_wx"], p["lru_bx"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lru_a"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_x = i * x.astype(jnp.float32)
    return a, jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * gated_x


def _combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2


def rglru_scan(x, p, h0=None, chunk: int = 256, unroll: bool = False):
    """x: (b, s, w) -> (y, h_last).

    Chunked: lax.scan over seq chunks carrying h, associative scan within a
    chunk — same math as one full-seq associative scan, but the compiled
    graph is chunk-sized (a full-seq associative scan at 512-device SPMD
    blows up partitioning time; the Pallas kernel repro.kernels.rglru_scan
    is the on-TPU fast path).
    """
    b, s, w = x.shape
    a, bx = _gates(x, p)  # (b, s, w) fp32

    def one_chunk(h, ai, bi):
        bi = bi.at[:, 0].add(ai[:, 0] * h)
        _, hh = jax.lax.associative_scan(_combine, (ai, bi), axis=1)
        return hh, hh[:, -1]

    h = (h0.astype(jnp.float32) if h0 is not None
         else jnp.zeros((b, w), jnp.float32))
    if s <= chunk or s % chunk:
        hh, h_last = one_chunk(h, a, bx)
        return hh.astype(x.dtype), h_last

    nc = s // chunk
    ac = a.reshape(b, nc, chunk, w).transpose(1, 0, 2, 3)
    bc = bx.reshape(b, nc, chunk, w).transpose(1, 0, 2, 3)

    def body(h, inp):
        ai, bi = inp
        hh, h_last = one_chunk(h, ai, bi)
        return h_last, hh

    if unroll:
        outs = []
        for i in range(nc):
            hh, h = one_chunk(h, ac[i], bc[i])
            outs.append(hh)
        ys = jnp.stack(outs)
        h_last = h
    else:
        h_last, ys = jax.lax.scan(body, h, (ac, bc))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, w)
    return y.astype(x.dtype), h_last


def rglru_step(x, p, h):
    """x: (b, 1, w), h: (b, w) -> (y (b,1,w), h')."""
    a, bx = _gates(x, p)
    h_new = a[:, 0] * h.astype(jnp.float32) + bx[:, 0]
    return h_new[:, None].astype(x.dtype), h_new


def causal_conv1d(x, w, b, state=None):
    """Depthwise causal conv. x: (b, s, c); w: (width, c).

    When ``state`` (b, width-1, c) is given, runs one-step decode and
    returns (y, new_state).
    """
    width = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)  # (b, width, c)
        y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                       w.astype(jnp.float32)) + b.astype(jnp.float32)
        return y[:, None].astype(x.dtype), window[:, 1:]
    pad = jnp.zeros(x.shape[:1] + (width - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(
        xp[:, i:i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
        for i in range(width)
    ) + b.astype(jnp.float32)
    return y.astype(x.dtype), xp[:, -(width - 1):] if width > 1 else None


def recurrent_block(x, p, cfg: ModelConfig, state=None):
    """RecurrentGemma recurrent block. x: (b, s, d).

    state: None (training/prefill from scratch) or dict(conv, h) for decode.
    Returns (y, new_state).
    """
    y = dense(x, p["w_y"])
    gate = jax.nn.gelu(dense(x, p["w_gate"]))
    if state is None:
        y, conv_state = causal_conv1d(y, p["conv_w"], p["conv_b"])
        y, h = rglru_scan(y, p, unroll=cfg.unroll_loops)
    else:
        y, conv_state = causal_conv1d(y, p["conv_w"], p["conv_b"], state["conv"])
        y, h = rglru_step(y, p, state["h"])
    out = dense(y * gate, p["w_out"])
    return out, {"conv": conv_state, "h": h}


def init_rec_state(cfg: ModelConfig, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }
