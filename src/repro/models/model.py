"""Family dispatch façade: one API for every architecture.

    loss_fn(cfg)    -> f(params, batch)          (mean loss, metrics)
    prefill_fn(cfg) -> f(params, batch)          (logits, cache)
    decode_fn(cfg)  -> f(params, token, cache)   (logits, cache)
    input_specs(cfg, shape)                      abstract batch for dry-run
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer, whisper
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.init import abstract_params, init_params  # noqa: F401

PyTree = Any


def loss_fn(cfg: ModelConfig) -> Callable:
    if cfg.family == "encdec":
        return lambda p, b: whisper.loss_fn(p, b, cfg)
    return lambda p, b: transformer.loss_fn(p, b, cfg)


def prefill_fn(cfg: ModelConfig) -> Callable:
    if cfg.family == "encdec":
        return lambda p, b: whisper.prefill(p, b, cfg)
    return lambda p, b: transformer.prefill(p, b, cfg)


def decode_fn(cfg: ModelConfig) -> Callable:
    if cfg.family == "encdec":
        return lambda p, t, c: whisper.decode_step(p, t, c, cfg)
    return lambda p, t, c: transformer.decode_step(p, t, c, cfg)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, abstract=False):
    return transformer.init_cache(cfg, batch, seq_len, abstract)


def supports_paged_decode(cfg: ModelConfig, max_len: int) -> bool:
    """Whether the batched paged-decode path can serve this config.

    dense/moe decoder caches page cleanly; hybrid/ssm carry recurrent
    states and encdec/vlm carry encoder context the block tables don't
    model, so those families fall back to the per-slot executor.  A
    sliding window narrower than ``max_len`` trims the prefill cache
    below full positional coverage, which the page scatter needs.
    """
    if cfg.family not in ("dense", "moe"):
        return False
    return cfg.attention_window == 0 or cfg.attention_window >= max_len


def paged_decode_fn(cfg: ModelConfig, attn_impl: str = "auto",
                    interpret: bool = False) -> Callable:
    """f(params, token, lengths, k_pages, v_pages, block_tables) ->
    (logits, k_pages, v_pages) — see transformer.paged_decode_step."""
    return lambda p, t, ln, kp, vp, bt: transformer.paged_decode_step(
        p, t, ln, kp, vp, bt, cfg, attn_impl=attn_impl, interpret=interpret)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                abstract: bool = True) -> Dict[str, Any]:
    """Abstract (ShapeDtypeStruct) model inputs for one assignment cell."""

    def arr(shp, dtype):
        return (jax.ShapeDtypeStruct(shp, dtype) if abstract
                else jnp.zeros(shp, dtype))

    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {}
        if cfg.family == "vlm":
            p = cfg.num_patches
            batch["tokens"] = arr((b, s - p), jnp.int32)
            batch["patches"] = arr((b, p, cfg.d_model), jnp.bfloat16)
        elif cfg.family == "encdec":
            batch["tokens"] = arr((b, s), jnp.int32)
            batch["frames"] = arr((b, cfg.encoder_positions, cfg.d_model),
                                  jnp.bfloat16)
        else:
            batch["tokens"] = arr((b, s), jnp.int32)
        return batch
    # decode: one new token against a seq_len-deep cache
    return {
        "token": arr((b,), jnp.int32),
        "cache": init_cache(cfg, b, s, abstract=abstract),
    }


def synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, key) -> Dict[str, Any]:
    """Concrete random inputs matching input_specs (for examples/benches)."""
    specs = input_specs(cfg, shape, abstract=True)

    def fill(spec):
        if jnp.issubdtype(spec.dtype, jnp.integer):
            return jax.random.randint(key, spec.shape, 0,
                                      min(cfg.vocab_size, 32_000),
                                      dtype=spec.dtype)
        return jnp.zeros(spec.shape, spec.dtype)

    return jax.tree.map(fill, specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
