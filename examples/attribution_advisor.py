"""Where did the goodput go — and which fix buys the most back?

Runs one scenario preset with the attribution waterfall attached, prints
the capacity waterfall (paper §6: per-layer lost chip-time), then asks
the what-if advisor to rank the counterfactual knob catalog by recovered
MPG (the paper's Fig 14/15 move).

    PYTHONPATH=src python examples/attribution_advisor.py [preset]
"""
import sys

from repro.fleet.advisor import what_if
from repro.fleet.scenarios import preset_names


def main(preset: str = "peak_week"):
    rep = what_if(preset, n_jobs=120, seed=0, n_pods=4, pod_size=128,
                  horizon=3 * 24 * 3600.0)
    base = rep["baseline"]
    wf = base["waterfall"]
    cap = wf["capacity_chip_time"]

    print(f"=== {preset}: baseline MPG composition ===")
    print("  " + "  ".join(f"{k}={base[k]:.3f}"
                           for k in ("SG", "RG", "PG", "MPG")))

    print("\n=== attribution waterfall (% of capacity chip-time) ===")
    print(f"  {'ideal (goodput)':26s} {100 * wf['ideal_chip_time'] / cap:5.1f}%")
    for row in wf["losses"]:
        label = f"{row['layer']}/{row['bucket']}"
        print(f"  {label:26s} {100 * row['frac_of_capacity']:5.1f}%")
    ok = wf["conservation"]["conserved"]
    print(f"  {'(conserves capacity)':26s} {'yes' if ok else 'NO'}")

    print("\n=== what-if advisor: recovered MPG per knob ===")
    for row in rep["ranking"]:
        print(f"  {row['knob']:26s} {row['recovered_mpg']:+.4f} MPG "
              f"({row['targets']}; dSG={row['d_sg']:+.3f} "
              f"dRG={row['d_rg']:+.3f} dPG={row['d_pg']:+.3f})")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] not in preset_names():
        raise SystemExit(f"unknown preset {sys.argv[1]!r}; "
                         f"choose from {preset_names()}")
    main(sys.argv[1] if len(sys.argv) > 1 else "peak_week")
