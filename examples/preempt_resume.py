"""Fault-tolerance demo: a training job is preempted mid-run, then a fresh
orchestrator restarts from the newest committed checkpoint; the rolled-back
work is booked as LOST (the paper's Runtime-Goodput definition).

    PYTHONPATH=src python examples/preempt_resume.py
"""
import tempfile

from repro.configs import get_smoke
from repro.runtime.orchestrator import Orchestrator, RunConfig


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="preempt_demo_")
    cfg = get_smoke("qwen2-72b")

    run1 = RunConfig(steps=30, batch=4, seq=64, checkpoint_every=8,
                     ckpt_dir=ckpt_dir, preempt_at_step=19)
    out1 = Orchestrator(cfg, run1).run()
    print(f"run 1: steps {out1['start_step']}..{out1['end_step']} "
          f"PREEMPTED={out1['preempted']} (checkpoints every 8)")

    run2 = RunConfig(steps=30, batch=4, seq=64, checkpoint_every=8,
                     ckpt_dir=ckpt_dir)
    out2 = Orchestrator(cfg, run2).run()
    print(f"run 2: resumed at step {out2['start_step']} "
          f"(newest committed checkpoint), finished at {out2['end_step']}")
    lost = out1['end_step'] - out2['start_step']
    print(f"work lost to the preemption: {lost} steps "
          f"(bounded by the checkpoint interval)")


if __name__ == "__main__":
    main()
