"""Simulate one week of a 4096-chip fleet and print the full MPG report
(the paper's Figure 10 breakdown + per-segment views).

    PYTHONPATH=src python examples/fleet_week.py
"""
from repro.core.goodput import (compute_goodput, rg_breakdown,
                                segment_goodput)
from repro.fleet.sim import FleetSim, SimConfig
from repro.fleet.workload import generate_jobs


def main():
    cfg = SimConfig(n_pods=16, pod_size=256, horizon=7 * 24 * 3600, seed=42)
    sim = FleetSim(cfg)
    for j in generate_jobs(400, cfg.horizon, seed=42,
                           capacity_chips=cfg.n_pods * cfg.pod_size,
                           target_load=0.6):
        sim.submit(j)
    sim.run()

    rep = compute_goodput(sim.intervals, sim.capacity_chip_time,
                          sim.pg_by_job())
    print("=== fleet MPG ===")
    for k, v in rep.as_dict().items():
        print(f"  {k:4s} {v:.3f}")
    print("\n=== where allocated time goes (RG breakdown) ===")
    for k, v in rg_breakdown(sim.intervals).items():
        print(f"  {k:12s} {v*100:5.1f}%")
    print("\n=== MPG by workload phase ===")
    by = segment_goodput(sim.intervals, "phase_kind",
                         {k: sim.capacity_chip_time
                          for k in ("train", "serve", "bulk_inference")},
                         sim.pg_by_job())
    for seg, r in by.items():
        print(f"  {seg:16s} RG={r.rg:.3f} PG={r.pg:.3f}")
    print("\n=== MPG by architecture (top 5 by chip-time) ===")
    by_arch = segment_goodput(sim.intervals, "arch", {}, sim.pg_by_job())
    top = sorted(by_arch.items(), key=lambda kv: -kv[1].allocated_chip_time)
    for seg, r in top[:5]:
        print(f"  {seg:24s} alloc={r.allocated_chip_time/3600:10.0f} chip-h "
              f"RG={r.rg:.3f} PG={r.pg:.3f}")
    print(f"\nfailures: {sum(j.failures for j in sim.jobs.values())}, "
          f"preemptions: {sum(j.preemptions for j in sim.jobs.values())}")


if __name__ == "__main__":
    main()
