"""Batched serving demo: prefill + continuous decode with a ring-buffer KV
cache on a reduced Mixtral (MoE + sliding-window attention).

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve


def main():
    serve.main(["--arch", "mixtral-8x7b", "--smoke", "--requests", "8",
                "--batch", "4", "--prompt-len", "24", "--max-new", "8"])


if __name__ == "__main__":
    main()
