"""Quickstart: train a reduced SmolLM on CPU with full MPG instrumentation.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.configs import get_smoke
from repro.core.goodput import compute_goodput, rg_breakdown
from repro.runtime.orchestrator import Orchestrator, RunConfig


def main():
    cfg = get_smoke("smollm-135m")
    run = RunConfig(steps=40, batch=8, seq=64, checkpoint_every=10,
                    async_checkpoint=True,
                    ckpt_dir=tempfile.mkdtemp(prefix="quickstart_"))
    orc = Orchestrator(cfg, run)
    out = orc.run()

    print(f"trained steps {out['start_step']}..{out['end_step']}  "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
    total = sum(i.chip_time for i in orc.intervals)
    rep = compute_goodput(orc.intervals, total)
    print(f"Runtime Goodput: {rep.rg:.3f}")
    for phase, frac in rg_breakdown(orc.intervals).items():
        print(f"  {phase:12s} {frac*100:5.1f}%")
    print(f"async-checkpoint device pause: "
          f"{out['ckpt_metrics']['device_pause_s']*1e3:.1f} ms total "
          f"(writes took {out['ckpt_metrics']['write_s']*1e3:.1f} ms off-path)")


if __name__ == "__main__":
    main()
