"""Paper Fig. 12: quantifying one compiler change's Program-Goodput impact
across a fixed benchmark of the top-150 fleet workloads.

The "compiler change" here is REAL: enabling mixed-precision parameter
gathering (bf16 casts before FSDP all-gathers, repro.launch.strategy) —
our analogue of the paper's XLA algebraic-simplification submit.  PG per
workload is computed from the roofline model (ideal/actual) before and
after; the figure's step-change is the mean PG jump across the benchmark.
"""
from __future__ import annotations

import random

from benchmarks.common import emit, save_json, timed
from repro.configs import ARCH_IDS, get_config
from repro.core.flops import model_flops
from repro.core.hardware import TPU_V5E
from repro.models.config import SHAPES_BY_NAME


def _workload_pg(arch: str, rng: random.Random, optimized: bool):
    """Roofline-modeled PG for one sampled workload of this arch."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME["train_4k"]
    mf = model_flops(cfg, shape)
    chips = rng.choice([64, 128, 256])
    t_ideal = mf / (chips * TPU_V5E.peak_flops_bf16)
    # actual = compute (with remat overhead) + exposed collective time
    compute_overhead = rng.uniform(1.30, 1.45)      # remat + attention
    coll_frac = rng.uniform(0.5, 1.1)               # collective / compute
    if optimized:
        coll_frac *= 0.5                            # bf16 gathers: 2x fewer bytes
    t_actual = t_ideal * compute_overhead * (1 + coll_frac)
    return t_ideal / t_actual


def run(n_workloads: int = 150, seed: int = 12):
    rng = random.Random(seed)
    archs = [rng.choice(ARCH_IDS) for _ in range(n_workloads)]
    before = [_workload_pg(a, random.Random(seed + i), False)
              for i, a in enumerate(archs)]
    after = [_workload_pg(a, random.Random(seed + i), True)
             for i, a in enumerate(archs)]
    mean_b = sum(before) / len(before)
    mean_a = sum(after) / len(after)
    improved = sum(1 for b, a in zip(before, after) if a > b)
    return {
        "n_workloads": n_workloads,
        "mean_pg_before": round(mean_b, 4),
        "mean_pg_after": round(mean_a, 4),
        "pg_uplift": round(mean_a / mean_b, 4),
        "workloads_improved": improved,
    }


def main(quick: bool = False):
    res, us = timed(lambda: run(50 if quick else 150))
    save_json("fleet/fig12_pg_compiler.json", res)
    emit("fig12_pg_compiler", us, res)
    return res


if __name__ == "__main__":
    print(main())
