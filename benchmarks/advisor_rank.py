"""Counterfactual advisor sweep: ranked recovered-MPG reports for every
scenario preset (paper §6–§7, the Fig 14/15 "which optimization buys the
most goodput back" question).

Every preset baseline runs with an attribution waterfall attached (each
run asserts exact chip-time conservation against its ledger), then the
full knob catalog is replayed on the byte-identical workload and ranked
by recovered MPG.  Emits ``results/fleet/advisor_rank.json``.

    PYTHONPATH=src python -m benchmarks.advisor_rank           # quick
    PYTHONPATH=src python -m benchmarks.advisor_rank --full
    PYTHONPATH=src python -m benchmarks.advisor_rank --tiny    # CI smoke
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit, save_json, timed
from repro.fleet.advisor import KNOBS, what_if
from repro.fleet.scenarios import GOLDEN_SIZE_MIX, SCENARIOS

SCALES = {
    # n_jobs, n_pods, pod_size, horizon
    "tiny": dict(n_jobs=24, seed=1234, n_pods=2, pod_size=64,
                 horizon=24 * 3600.0, size_mix=GOLDEN_SIZE_MIX),
    "quick": dict(n_jobs=150, seed=0, n_pods=4, pod_size=256,
                  horizon=5 * 24 * 3600.0),
    "full": dict(n_jobs=400, seed=0, n_pods=8, pod_size=256,
                 horizon=14 * 24 * 3600.0),
}


def _round_row(row: dict) -> dict:
    return {k: (round(v, 6) if isinstance(v, float) else v)
            for k, v in row.items()}


def run(scale: str = "quick") -> dict:
    knobs = SCALES[scale]
    scenarios: dict = {}
    for name in sorted(SCENARIOS):
        rep = what_if(name, **knobs)
        base = rep["baseline"]
        wf = base["waterfall"]
        scenarios[name] = {
            "baseline": {k: round(base[k], 4)
                         for k in ("SG", "RG", "PG", "MPG")},
            "conserved": wf["conservation"]["conserved"],
            "lost_by_layer": {k: round(v / wf["capacity_chip_time"], 4)
                              for k, v in wf["lost_by_layer"].items()},
            "ranking": [_round_row({k: r[k] for k in (
                "knob", "targets", "MPG", "recovered_mpg",
                "d_sg", "d_rg", "d_pg")}) for r in rep["ranking"]],
        }

    def recovered(preset, knob):
        return next(r["recovered_mpg"] for r in scenarios[preset]["ranking"]
                    if r["knob"] == knob)

    checks = {
        "n_scenarios": len(scenarios),
        "n_knobs": len(KNOBS),
        "all_conserved": all(s["conserved"] for s in scenarios.values()),
        # paper Fig 14 qualitative order on the steady fleet: async
        # checkpointing is the headline RG optimization, ahead of the
        # compile cache and the single-controller framework migration
        "fig14_async_leads": all(
            recovered("steady", "async_checkpointing") >=
            recovered("steady", other)
            for other in ("compile_cache_warm", "single_controller")),
        # generation upgrade is a PG knob: it only pays on hetero fleets
        "gen_upgrade_pays_on_hetero": (
            recovered("hetero_fleet", "generation_upgrade") >
            recovered("steady", "generation_upgrade")),
        # the paper-policy swap is a no-op on presets already running the
        # paper combination (the advisor must not invent phantom gains)
        "policy_swap_noop_on_paper_baseline":
            recovered("steady", "scheduler_paper_policies") == 0.0,
    }
    return {"scale": scale, "knob_catalog": sorted(KNOBS),
            "scenarios": scenarios, "checks": checks}


def main(quick: bool = True, scale: str = None):
    scale = scale or ("quick" if quick else "full")
    res, us = timed(lambda: run(scale=scale))
    save_json("fleet/advisor_rank.json", res)
    emit("advisor_rank", us, res["checks"])
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI smoke scale")
    ap.add_argument("--full", action="store_true", help="paper scale")
    args = ap.parse_args()
    main(scale="tiny" if args.tiny else ("full" if args.full else "quick"))
