"""Paper Fig. 14 / §5.2: Runtime-Goodput optimizations over a quarter,
segmented by workload type.

Reproduced optimizations (each a real subsystem in this framework):
  * async checkpointing (runtime.checkpoint)      -> RG up for ckpt-heavy jobs
  * AOT compilation cache (runtime.compile_cache) -> INIT time down
  * Pathways-style single-client framework        -> lower init + stalls

Speedups are normalized to the top-N fleet workloads at quarter start,
exactly like the paper's figure.
"""
from __future__ import annotations

from benchmarks.common import emit, save_json, timed
from repro.fleet.sim import FleetSim, SimConfig
from repro.fleet.workload import generate_jobs


def fleet_rg(seed, *, async_ckpt=False, cache=False, pathways_frac=0.7):
    # month-long sims: stream into the ledger, never keep the interval list
    cfg = SimConfig(n_pods=8, pod_size=256, horizon=30 * 24 * 3600,
                    seed=seed, retain_intervals=False)
    sim = FleetSim(cfg)
    for j in generate_jobs(300, cfg.horizon, seed=seed,
                           async_checkpoint=async_ckpt, compile_cache=cache,
                           framework_mix=pathways_frac,
                           capacity_chips=cfg.n_pods * cfg.pod_size):
        sim.submit(j)
    sim.run()
    return sim.report().rg


def run(seed: int = 14):
    base = fleet_rg(seed)
    rows = {
        "baseline": 1.0,
        "async_checkpoint": fleet_rg(seed, async_ckpt=True) / base,
        "aot_compile_cache": fleet_rg(seed, cache=True) / base,
        "pathways_single_client": fleet_rg(seed, pathways_frac=1.0) / base,
        "all_three": fleet_rg(seed, async_ckpt=True, cache=True,
                              pathways_frac=1.0) / base,
    }
    return {"rg_speedup_vs_baseline": {k: round(v, 4) for k, v in rows.items()},
            "baseline_rg": round(base, 4)}


def main(quick: bool = False):
    res, us = timed(lambda: run())
    save_json("fleet/fig14_rg_optimizations.json", res)
    emit("fig14_rg_optimizations", us, res["rg_speedup_vs_baseline"])
    return res


if __name__ == "__main__":
    print(main())
