"""Closed-loop controller benchmark: regret vs static policies, plus an
adversarially-searched worst-case suite (BENCH_controller.json).

The advisor ranks fixes after the fact; the adaptive controller
(``repro.fleet.controller``) reacts during the run.  This benchmark asks
the question that justifies its existence: *does closing the loop beat
committing to any single static policy up front?*  Three arms per
scenario preset, identical workload and cluster:

  * ``rigid``   — every job inelastic (the conservative static fleet);
  * ``elastic`` — every job elastic (the aggressive static fleet);
  * ``controlled`` — the rigid workload plus the online controller,
    which may flip the fleet elastic, evict stalled gangs, retune Daly
    checkpoint intervals from the observed failure rate, and switch
    scheduler policies — paying a visible ``policy_switch`` interval per
    decision;

and three committed gates:

  (a) per-preset regret vs the *oracle* static arm (the better of
      rigid/elastic chosen per scenario, by sweep) stays within 5%;
  (b) the controlled arm's MPG averaged across all 7 presets is strictly
      above the best *single* static arm's average — no one static
      policy matches adapting;
  (c) on every scenario in the committed adversarial suite — found by a
      seeded random-restart hill-climber (``repro.fleet.adversary``)
      mutating burst/MTBF/maintenance/arrival/repair parameters to
      minimize *controlled* MPG — the controlled arm still meets the
      best static arm's MPG (within the same 5% regret band, and above
      it in the committed suite).

The sim is deterministic and the controller consumes only
engine-identical state, so ``--check`` is exact: the tiny section re-runs
(including the adversarial re-evaluation) and every MPG must match the
committed floats bit-for-bit; the controlled arm additionally runs under
both engines and must stream identical ledger totals and switch logs.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import pathlib
import resource
import sys
import time
from typing import Dict, Optional

from repro.core.attribution import AttributionWaterfall
from repro.fleet.adversary import scenario_from, search_worst
from repro.fleet.advisor import SATURATED_LOAD
from repro.fleet.controller import AdaptiveController
from repro.fleet.scenarios import GOLDEN_SIZE_MIX, SCENARIOS, Scenario, \
    build_sim

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_controller.json"
DAY = 24 * 3600.0

PRESETS = tuple(SCENARIOS)            # all 7
REGRET_BAND = 0.05                    # gate (a): relative MPG regret
REPAIR_S = 4 * 3600.0                 # repair window that makes the
                                      # rigid/elastic trade real

TINY = {"n_jobs": 24, "seed": 1234, "n_pods": 2, "pod_size": 64,
        "horizon_days": 1.0, "size_mix": GOLDEN_SIZE_MIX,
        "slice_repair_s": REPAIR_S, "target_load": SATURATED_LOAD}
FULL = {"n_jobs": 200, "seed": 42, "n_pods": 8, "pod_size": 256,
        "horizon_days": 7.0, "size_mix": None,
        "slice_repair_s": REPAIR_S, "target_load": SATURATED_LOAD}

ADVERSARY = {"seed": 1234, "restarts": 3, "steps": 8, "keep": 3}


def _fingerprint(cfg: Dict) -> str:
    return hashlib.sha256(
        json.dumps(cfg, sort_keys=True).encode()).hexdigest()[:16]


def _peak_rss_mb() -> float:
    # ru_maxrss is KiB on Linux, bytes on macOS
    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":
        peak /= 1024
    return round(peak / 1024, 1)


def _mutator(elastic: Optional[bool]):
    if elastic is None:
        return None
    return lambda j: dataclasses.replace(j, elastic=elastic)


def _build(scenario: Scenario, cfg: Dict, *, elastic: Optional[bool],
           controller: Optional[AdaptiveController] = None,
           engine: str = "vectorized",
           slice_repair_s: Optional[float] = None):
    scenario = dataclasses.replace(scenario,
                                   target_load=cfg["target_load"])
    return build_sim(scenario, n_jobs=cfg["n_jobs"], seed=cfg["seed"],
                     n_pods=cfg["n_pods"], pod_size=cfg["pod_size"],
                     horizon=cfg["horizon_days"] * DAY,
                     size_mix=cfg["size_mix"],
                     slice_repair_s=(cfg["slice_repair_s"]
                                     if slice_repair_s is None
                                     else slice_repair_s),
                     engine=engine, retain_intervals=False,
                     job_mutator=_mutator(elastic), controller=controller)


def _run_arm(scenario: Scenario, cfg: Dict, *, elastic: Optional[bool],
             controlled: bool = False, **build_kw) -> Dict:
    ctrl = AdaptiveController() if controlled else None
    sim = _build(scenario, cfg, elastic=elastic, controller=ctrl,
                 **build_kw)
    wf = ctrl.waterfall if ctrl else \
        AttributionWaterfall().attach(sim.ledger)
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    wf.assert_conserves(sim.ledger)
    rep = sim.report()
    wfr = wf.report()
    out = {
        "SG": round(rep.sg, 6), "RG": round(rep.rg, 6),
        "PG": round(rep.pg, 6), "MPG": round(rep.mpg, 6),
        "failures": sum(r.failures for r in sim.jobs.values()),
        "lost_by_layer": {k: round(v, 1)
                          for k, v in wfr["lost_by_layer"].items()},
        "wall_s": round(wall, 3),
    }
    if ctrl is not None:
        out["switches"] = [
            {"t": s["t"], "rule": s["rule"], "mode": s["mode"]}
            for s in ctrl.switches]
        out["policy_switch_chip_time"] = round(
            wf.bucket_totals().get("policy_switch", 0.0), 1)
    return out


def _equivalence(scenario: Scenario, cfg: Dict, **build_kw) -> Dict:
    """The controlled arm under both engines must stream bit-identical
    ledger totals AND take the identical switch sequence."""
    runs = {}
    for engine in ("vectorized", "reference"):
        ctrl = AdaptiveController()
        sim = _build(scenario, cfg, elastic=False, controller=ctrl,
                     engine=engine, **build_kw)
        sim.run()
        runs[engine] = (sim.ledger.totals(), ctrl.switches)
    tv, sv = runs["vectorized"]
    tr, sr = runs["reference"]
    assert tv == tr, f"engines diverged on {scenario.name}: {tv} != {tr}"
    assert sv == sr, (f"switch logs diverged on {scenario.name}: "
                      f"{sv} != {sr}")
    return {"n_events": tv["n_events"], "n_switches": len(sv),
            "engines_identical": True}


def _preset_section(preset: str, cfg: Dict, cross_engine: bool) -> Dict:
    scenario = SCENARIOS[preset]
    rigid = _run_arm(scenario, cfg, elastic=False)
    elastic = _run_arm(scenario, cfg, elastic=True)
    controlled = _run_arm(scenario, cfg, elastic=False, controlled=True)
    oracle = max(("rigid", "elastic"),
                 key=lambda a: {"rigid": rigid, "elastic": elastic}[a]["MPG"])
    best = {"rigid": rigid, "elastic": elastic}[oracle]["MPG"]
    layers = sorted(set(rigid["lost_by_layer"])
                    | set(controlled["lost_by_layer"]))
    section = {
        "rigid": rigid,
        "elastic": elastic,
        "controlled": controlled,
        "oracle_static": oracle,
        "best_static_mpg": best,
        # relative regret vs the per-scenario oracle; negative means the
        # controller beat every static arm outright
        "regret_mpg": round((best - controlled["MPG"]) / best, 6),
        # positive = chip-time the rigid static arm lost in that layer
        # and the controlled arm recovered
        "recovered_by_layer": {
            k: round(rigid["lost_by_layer"].get(k, 0.0)
                     - controlled["lost_by_layer"].get(k, 0.0), 1)
            for k in layers},
    }
    if cross_engine:
        section["equivalence"] = _equivalence(scenario, cfg)
    return section


def _scale_section(cfg: Dict, cross_engine: bool) -> Dict:
    section: Dict[str, object] = {
        "config": {**cfg, "repair_hours": cfg["slice_repair_s"] / 3600.0},
        "config_fingerprint": _fingerprint(cfg),
    }
    avgs = {"rigid": 0.0, "elastic": 0.0, "controlled": 0.0}
    for preset in PRESETS:
        section[preset] = _preset_section(preset, cfg, cross_engine)
        for arm in avgs:
            avgs[arm] += section[preset][arm]["MPG"] / len(PRESETS)
    best_arm = max(("rigid", "elastic"), key=lambda a: avgs[a])
    section["summary"] = {
        "avg_mpg": {k: round(v, 6) for k, v in avgs.items()},
        "best_static_arm": best_arm,
        # gate (b): adapting beats committing to the best single policy
        "controller_beats_best_static_avg":
            bool(avgs["controlled"] > avgs[best_arm]),
        "max_regret_mpg": max(section[p]["regret_mpg"] for p in PRESETS),
    }
    return section


def _adversarial_section(cfg: Dict) -> Dict:
    """Hill-climb scenario space against the *controlled* arm, then
    re-score the static arms on every kept worst case (gate (c))."""

    def evaluate(genome) -> float:
        scenario = scenario_from(genome)
        out = _run_arm(scenario, cfg, elastic=False, controlled=True,
                       slice_repair_s=genome["repair_hours"] * 3600.0)
        return out["MPG"]

    worst = search_worst(evaluate, seed=ADVERSARY["seed"],
                         restarts=ADVERSARY["restarts"],
                         steps=ADVERSARY["steps"],
                         keep=ADVERSARY["keep"])
    suite = []
    for i, entry in enumerate(worst):
        genome = entry["genome"]
        scenario = scenario_from(genome, name=f"adversarial_{i}")
        repair = genome["repair_hours"] * 3600.0
        arms = {
            "controlled": _run_arm(scenario, cfg, elastic=False,
                                   controlled=True,
                                   slice_repair_s=repair),
            "rigid": _run_arm(scenario, cfg, elastic=False,
                              slice_repair_s=repair),
            "elastic": _run_arm(scenario, cfg, elastic=True,
                                slice_repair_s=repair),
        }
        best = max(arms["rigid"]["MPG"], arms["elastic"]["MPG"])
        suite.append({
            "name": scenario.name,
            "genome": genome,
            "controlled_mpg": arms["controlled"]["MPG"],
            "rigid_mpg": arms["rigid"]["MPG"],
            "elastic_mpg": arms["elastic"]["MPG"],
            "best_static_mpg": best,
            "controller_survives":
                bool(arms["controlled"]["MPG"] >= best),
            "n_switches": len(arms["controlled"]["switches"]),
        })
    return {"search": dict(ADVERSARY), "config": dict(cfg),
            "config_fingerprint": _fingerprint(cfg), "suite": suite}


def _load_committed() -> Dict:
    if BENCH_PATH.exists():
        return json.loads(BENCH_PATH.read_text())
    return {}


def _write(bench: Dict) -> None:
    bench["version"] = 1
    bench["generated_by"] = "benchmarks/controller.py"
    bench["peak_rss_mb"] = _peak_rss_mb()
    BENCH_PATH.write_text(json.dumps(bench, indent=1, sort_keys=True) + "\n")


def check(fresh_tiny: Dict, fresh_adv: Dict, committed: Dict) -> None:
    """CI gate: (a) per-preset regret inside the band, (b) controlled
    average above the best static average, (c) the controller survives
    every committed adversarial scenario — then exact-float comparison
    against the committed baseline (same fingerprint => same floats)."""
    for preset in PRESETS:
        regret = fresh_tiny[preset]["regret_mpg"]
        if regret > REGRET_BAND:
            raise SystemExit(
                f"controller --check FAILED: regret on {preset} is "
                f"{regret:.4f} > {REGRET_BAND} vs the "
                f"{fresh_tiny[preset]['oracle_static']} oracle")
    if not fresh_tiny["summary"]["controller_beats_best_static_avg"]:
        raise SystemExit(
            "controller --check FAILED: controlled average "
            f"{fresh_tiny['summary']['avg_mpg']} does not beat the best "
            "static arm")
    for row in fresh_adv["suite"]:
        if not row["controller_survives"]:
            raise SystemExit(
                f"controller --check FAILED: adversarial scenario "
                f"{row['name']} (genome {row['genome']}) drives the "
                f"controlled MPG {row['controlled_mpg']} below the best "
                f"static arm {row['best_static_mpg']}")
    base = committed.get("tiny")
    if not base or \
            base.get("config_fingerprint") != fresh_tiny["config_fingerprint"]:
        print("controller --check: no comparable committed tiny baseline; "
              "gates (a)-(c) only")
        return
    for preset in PRESETS:
        for arm in ("rigid", "elastic", "controlled"):
            got = fresh_tiny[preset][arm]["MPG"]
            want = base[preset][arm]["MPG"]
            if got != want:
                raise SystemExit(
                    f"controller --check FAILED: {preset}/{arm} MPG {got} "
                    f"!= committed {want} (deterministic sim — a semantic "
                    "change must re-bless BENCH_controller.json)")
    badv = committed.get("adversarial")
    if badv and badv.get("config_fingerprint") == \
            fresh_adv["config_fingerprint"] and \
            badv.get("search") == fresh_adv["search"]:
        for got, want in zip(fresh_adv["suite"], badv["suite"]):
            if got["genome"] != want["genome"] or \
                    got["controlled_mpg"] != want["controlled_mpg"]:
                raise SystemExit(
                    "controller --check FAILED: adversarial suite drifted "
                    f"from committed ({got['name']}): {got} != {want}")
    print("controller --check OK: regret <= "
          f"{REGRET_BAND} on {len(PRESETS)} presets, controlled avg beats "
          "best static, controller survives the adversarial suite, exact "
          "match vs committed baseline")


def main(tiny: bool = False, do_check: bool = False) -> Dict:
    committed = _load_committed()
    bench = dict(committed)
    t_start = time.monotonic()
    fresh_tiny = _scale_section(TINY, cross_engine=True)
    bench["tiny"] = fresh_tiny
    fresh_adv = _adversarial_section(TINY)
    bench["adversarial"] = fresh_adv
    if do_check:
        check(fresh_tiny, fresh_adv, committed)
    if not tiny:
        bench["full"] = _scale_section(FULL, cross_engine=False)
    _write(bench)
    wall_us = (time.monotonic() - t_start) * 1e6
    derived = {
        "tiny_max_regret": fresh_tiny["summary"]["max_regret_mpg"],
        "tiny_ctrl_avg": fresh_tiny["summary"]["avg_mpg"]["controlled"],
        "adv_survived": all(r["controller_survives"]
                            for r in fresh_adv["suite"]),
    }
    if "full" in bench:
        derived["full_max_regret"] = \
            bench["full"]["summary"]["max_regret_mpg"]
        derived["full_ctrl_avg"] = \
            bench["full"]["summary"]["avg_mpg"]["controlled"]
    print(f"controller,{wall_us:.1f},{json.dumps(derived, sort_keys=True)}")
    return bench


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny arms + adversarial suite only")
    ap.add_argument("--check", action="store_true",
                    help="enforce gates (a)-(c) and exact-float match vs "
                         "the committed BENCH_controller.json")
    args = ap.parse_args()
    main(tiny=args.tiny, do_check=args.check)
