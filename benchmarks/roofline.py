"""Deliverable (g): per-(arch x shape x mesh) three-term roofline table.

Inputs:
  results/dryrun/*.json   — sharded-compile memory + collective traffic
  results/costref/*.json  — single-device cost-reference (flops/bytes),
                            computed on demand (cached).

Output: results/roofline/table.json + a printed markdown table; the fleet
workload generator seeds per-arch PG from this file.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import RESULTS, emit, save_json, timed
from repro.configs import ARCH_IDS, get_config
from repro.core.costref import cost_reference
from repro.core.roofline import make_cell
from repro.models.config import SHAPES, SHAPES_BY_NAME, shape_applicable

DRYRUN = RESULTS / "dryrun"


def build_table(mesh: str = "16x16", archs=None, quick=False):
    rows = []
    for arch in (archs or ARCH_IDS):
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            dr = DRYRUN / f"{arch}__{shape.name}__{mesh}.json"
            if not dr.exists():
                continue
            rec = json.loads(dr.read_text())
            ref = cost_reference(cfg, shape)
            cell = make_cell(
                cfg, shape, mesh, rec["chips"],
                hlo_flops=ref["flops"], hlo_bytes=ref["bytes"],
                collective_bytes_per_chip=rec["collectives"]["total_bytes"])
            row = cell.row()
            row["fits_hbm"] = (
                (rec["memory"]["argument_bytes"] or 0)
                + (rec["memory"]["temp_bytes"] or 0)
                <= rec["memory"]["hbm_per_chip"])
            row["peak_gib"] = round(
                ((rec["memory"]["argument_bytes"] or 0)
                 + (rec["memory"]["temp_bytes"] or 0)) / 2**30, 2)
            rows.append(row)
    return rows


def render_markdown(rows) -> str:
    hdr = ("| arch | shape | chips | t_comp (ms) | t_mem (ms) | t_coll (ms) "
           "| dominant | useful | PG(overlap) | fits |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} "
            f"| {r['t_compute_s']*1e3:9.2f} | {r['t_memory_s']*1e3:9.2f} "
            f"| {r['t_collective_s']*1e3:9.2f} | {r['dominant']:10s} "
            f"| {r['useful_ratio']:.2f} | {r['pg_overlap']:.3f} "
            f"| {'Y' if r['fits_hbm'] else 'OVER'} |")
    return "\n".join(lines)


def main(quick: bool = False):
    def run():
        rows = build_table("16x16",
                           archs=["smollm-135m"] if quick else None,
                           quick=quick)
        save_json("roofline/table.json", rows)
        (RESULTS / "roofline" / "table.md").write_text(render_markdown(rows))
        return rows

    rows, us = timed(run)
    derived = {"cells": len(rows),
               "dominant_counts": {}}
    for r in rows:
        derived["dominant_counts"][r["dominant"]] = \
            derived["dominant_counts"].get(r["dominant"], 0) + 1
    emit("roofline_table", us, derived)
    if rows:
        print(render_markdown(rows))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(ap.parse_args().quick)
