"""Shared benchmark plumbing: timing + the run.py CSV contract
(``name,us_per_call,derived``)."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Any, Callable, Dict, List

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"


def timed(fn: Callable[[], Any]) -> tuple:
    t0 = time.monotonic()
    out = fn()
    return out, (time.monotonic() - t0) * 1e6


def emit(name: str, us_per_call: float, derived: Dict[str, Any]):
    """One CSV row per paper table/figure."""
    print(f"{name},{us_per_call:.1f},{json.dumps(derived, sort_keys=True)}")


def save_json(rel: str, obj: Any):
    p = RESULTS / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(obj, indent=1, default=str))
    return p
