"""Benchmark harness entry point (deliverable d).

One function per paper table/figure; prints ``name,us_per_call,derived``
CSV rows.  ``python -m benchmarks.run`` runs the fleet-scale benches in
quick mode; pass --full for the paper-scale populations and the roofline
table (requires the dry-run artifacts; see repro.launch.dryrun).
"""
from __future__ import annotations

import argparse
import cProfile
import pstats
import sys
import traceback

from benchmarks import (advisor_rank, fig4_job_sizes, fig12_pg_compiler,
                        fig14_rg_optimizations, fig15_rg_phases,
                        fig16_sg_by_size, fleet_scale, ledger_scale,
                        overlap_speedup, paged_decode, roofline,
                        scenario_sweep, serve_scale, table2_mpg_composition)
from benchmarks.common import RESULTS

BENCHES = [
    ("fig4_job_sizes", fig4_job_sizes.main),
    ("fig12_pg_compiler", fig12_pg_compiler.main),
    ("fig14_rg_optimizations", fig14_rg_optimizations.main),
    ("fig15_rg_phases", fig15_rg_phases.main),
    ("fig16_sg_by_size", fig16_sg_by_size.main),
    ("table2_mpg_composition", table2_mpg_composition.main),
    ("ledger_scale", ledger_scale.main),
    ("fleet_scale", fleet_scale.main),
    ("serve_scale", serve_scale.main),
    ("paged_decode", paged_decode.main),
    ("scenario_sweep", scenario_sweep.main),
    ("advisor_rank", advisor_rank.main),
    ("overlap_speedup", overlap_speedup.main),
    ("roofline_table", roofline.main),
]


def _run_profiled(name: str, fn, quick: bool) -> None:
    """cProfile one bench into results/profiles/<name>.pstats and print
    the top-25 cumulative entries, so hot-path regressions are
    diagnosable straight from a CI artifact."""
    prof_dir = RESULTS / "profiles"
    prof_dir.mkdir(parents=True, exist_ok=True)
    out = prof_dir / f"{name}.pstats"
    prof = cProfile.Profile()
    prof.enable()
    try:
        fn(quick=quick)
    finally:
        prof.disable()
        prof.dump_stats(out)
        print(f"# profile written: {out}", file=sys.stderr)
        stats = pstats.Stats(prof, stream=sys.stderr)
        stats.sort_stats("cumulative").print_stats(25)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale populations (slower)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--profile", action="store_true",
                    help="cProfile each bench into results/profiles/"
                         "<bench>.pstats and print the top-25 cumulative")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        try:
            if args.profile:
                _run_profiled(name, fn, quick=not args.full)
            else:
                fn(quick=not args.full)
        except Exception as e:  # noqa: BLE001 - report and continue
            failures += 1
            print(f'{name},-1,"ERROR: {type(e).__name__}: {e}"')
            traceback.print_exc(limit=2, file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
