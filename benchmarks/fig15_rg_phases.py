"""Paper Fig. 15: Runtime Goodput by workload phase over six months.

Claims reproduced: training RG > serving RG (steady vs fluctuating
demand); bulk-inference RG dips when model weights become sharded across
chips (expensive reads) — the paper's Month-3..6 transient.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, save_json, timed
from repro.fleet.sim import FleetSim, SimConfig
from repro.fleet.workload import generate_jobs


def run(seed: int = 15, months: int = 6):
    month = 30 * 24 * 3600.0
    series = {"train": [], "serve": [], "bulk_inference": []}
    for m in range(months):
        cfg = SimConfig(n_pods=8, pod_size=256, horizon=month,
                        seed=seed + m, retain_intervals=False)
        sim = FleetSim(cfg)
        jobs = generate_jobs(300, cfg.horizon, seed=seed + m,
                             capacity_chips=cfg.n_pods * cfg.pod_size)
        for j in jobs:
            if j.phase_kind == "bulk_inference" and m >= 3:
                # large sharded-weight era: slower restarts + heavier stalls
                j = dataclasses.replace(
                    j, data_stall_frac=min(0.5, j.data_stall_frac * 4),
                    init_time=j.init_time * 2)
            if j.phase_kind == "serve":
                # fluctuating demand: serving jobs churn (short, frequent)
                j = dataclasses.replace(j, work=j.work * 0.3)
            sim.submit(j)
        sim.run()
        cap = sim.capacity_chip_time
        by = sim.ledger.segment_report("phase_kind",
                                       {k: cap for k in series})
        for k in series:
            series[k].append(round(by[k].rg, 4) if k in by else None)
    return {"rg_by_month": series}


def main(quick: bool = False):
    res, us = timed(lambda: run(months=3 if quick else 6))
    save_json("fleet/fig15_rg_phases.json", res)
    s = res["rg_by_month"]
    derived = {
        "train_gt_serve": all(a > b for a, b in zip(s["train"], s["serve"])
                              if a and b),
        "bulk_dips_after_sharding": (s["bulk_inference"][-1]
                                     < s["bulk_inference"][0]),
        "final": {k: v[-1] for k, v in s.items()},
    }
    emit("fig15_rg_phases", us, derived)
    return res


if __name__ == "__main__":
    print(main())
