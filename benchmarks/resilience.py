"""Resiliency benchmark: rigid vs elastic gangs under failure presets
(BENCH_resilience.json).

The paper's pod-scale resiliency question: when a slice of a multi-slice
gang dies (or a maintenance wave drains pods), is it better to hold the
survivors and wait for replacement hardware (rigid), or to reshard onto
the survivors and keep training degraded (elastic)?  This benchmark
answers it with MPG, per layer, at equal capacity:

  * both arms run the *same* workload on the *same* cluster under the
    same scenario seed — the only difference is every job's ``elastic``
    flag (the ``job_mutator`` hook, exactly how the what-if advisor
    applies counterfactuals);
  * the fleet is saturated (``SATURATED_LOAD``) and failed hardware
    takes a repair window (``slice_repair_s``) to return — the regime
    where the trade is real.  With instant repair a rigid gang's refill
    is granted on the spot and neither arm can win;
  * two sections, ``tiny`` (the golden-trace scale; seconds, run by CI)
    and ``full`` (the paper-scale sweep); each records the MPG
    composition, failure/preemption counts, reshard and gang-stall
    chip-time, the attribution waterfall's per-layer losses, and the
    headline ``recovered_mpg = elastic.MPG - rigid.MPG``;
  * the tiny section's elastic arm runs under BOTH engines and asserts
    bit-identical ledger totals — the cross-engine equivalence gate
    extended to the repair-window machinery;
  * an ``advisor`` section ranks the resiliency knobs
    (``elastic_resize``, ``multi_slice_gang``) on the same failure
    preset, tying the benchmark to the counterfactual advisor.

The sim is deterministic, so ``--check`` can be exact: it re-runs the
tiny section and fails if elastic stops beating rigid on either preset,
or if any recovered-MPG value drifts from the committed baseline (same
config fingerprint => same floats, on any machine).
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import pathlib
import resource
import sys
import time
from typing import Dict, Optional

from repro.core.attribution import AttributionWaterfall
from repro.core.goodput import Phase
from repro.fleet.advisor import SATURATED_LOAD, what_if
from repro.fleet.scenarios import (GOLDEN_SIZE_MIX, SCENARIOS, build_sim)

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_resilience.json"
DAY = 24 * 3600.0

PRESETS = ("failure_storm", "maintenance")

# hardware repair SLA: a failed slice's chips return to the allocator
# after this long (swap + triage); the window that makes rigid gangs'
# replacement waits — and elastic's degraded-throughput trade — real
REPAIR_S = 4 * 3600.0

TINY = {"n_jobs": 24, "seed": 1234, "n_pods": 2, "pod_size": 64,
        "horizon_days": 1.0, "size_mix": GOLDEN_SIZE_MIX,
        "slice_repair_s": REPAIR_S, "target_load": SATURATED_LOAD}
FULL = {"n_jobs": 200, "seed": 42, "n_pods": 8, "pod_size": 256,
        "horizon_days": 7.0, "size_mix": None,
        "slice_repair_s": REPAIR_S, "target_load": SATURATED_LOAD}


def _fingerprint(cfg: Dict) -> str:
    return hashlib.sha256(
        json.dumps(cfg, sort_keys=True).encode()).hexdigest()[:16]


def _peak_rss_mb() -> float:
    # ru_maxrss is KiB on Linux, bytes on macOS
    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":
        peak /= 1024
    return round(peak / 1024, 1)


def _build(preset: str, cfg: Dict, elastic: bool, engine: str):
    scenario = dataclasses.replace(SCENARIOS[preset],
                                   target_load=cfg["target_load"])
    mutator = (lambda j: dataclasses.replace(j, elastic=elastic))
    return build_sim(scenario, n_jobs=cfg["n_jobs"], seed=cfg["seed"],
                     n_pods=cfg["n_pods"], pod_size=cfg["pod_size"],
                     horizon=cfg["horizon_days"] * DAY,
                     size_mix=cfg["size_mix"],
                     slice_repair_s=cfg["slice_repair_s"],
                     engine=engine, retain_intervals=False,
                     job_mutator=mutator)


def _run_arm(preset: str, cfg: Dict, elastic: bool,
             engine: str = "vectorized") -> Dict:
    sim = _build(preset, cfg, elastic, engine)
    wf = AttributionWaterfall().attach(sim.ledger)
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    wf.assert_conserves(sim.ledger)
    rep = sim.report()
    wfr = wf.report()
    buckets = {r["bucket"]: r["chip_time"] for r in wfr["losses"]}
    runtimes = list(sim.jobs.values())
    return {
        "SG": round(rep.sg, 6), "RG": round(rep.rg, 6),
        "PG": round(rep.pg, 6), "MPG": round(rep.mpg, 6),
        "failures": sum(r.failures for r in runtimes),
        "preemptions": sum(r.preemptions for r in runtimes),
        "reshard_chip_time": round(
            sim.ledger.phase_chip_time(Phase.RESHARD), 1),
        "gang_stall_chip_time": round(buckets.get("gang_stall", 0.0), 1),
        "lost_by_layer": {k: round(v, 1)
                          for k, v in wfr["lost_by_layer"].items()},
        "wall_s": round(wall, 3),
    }


def _equivalence_totals(preset: str, cfg: Dict) -> Dict:
    """Both engines on the elastic arm must stream bit-identical ledger
    totals — the golden-trace equivalence bar, under a repair window."""
    tv = _build(preset, cfg, True, "vectorized")
    tr = _build(preset, cfg, True, "reference")
    tv.run()
    tr.run()
    a, b = tv.ledger.totals(), tr.ledger.totals()
    assert a == b, f"engines diverged on {preset}: {a} != {b}"
    return {"n_events": a["n_events"], "engines_identical": True}


def _preset_section(preset: str, cfg: Dict, cross_engine: bool) -> Dict:
    rigid = _run_arm(preset, cfg, elastic=False)
    elastic = _run_arm(preset, cfg, elastic=True)
    layers = sorted(set(rigid["lost_by_layer"]) | set(elastic["lost_by_layer"]))
    section = {
        "rigid": rigid,
        "elastic": elastic,
        "recovered_mpg": round(elastic["MPG"] - rigid["MPG"], 6),
        # positive = elastic sheds loss in that layer (chip-time the
        # rigid arm burned there and the elastic arm did not)
        "recovered_by_layer": {
            k: round(rigid["lost_by_layer"].get(k, 0.0)
                     - elastic["lost_by_layer"].get(k, 0.0), 1)
            for k in layers},
    }
    if cross_engine:
        section["equivalence"] = _equivalence_totals(preset, cfg)
    return section


def _scale_section(cfg: Dict, cross_engine: bool) -> Dict:
    section: Dict[str, object] = {
        "config": {**cfg, "repair_hours": cfg["slice_repair_s"] / 3600.0},
        "config_fingerprint": _fingerprint(cfg),
    }
    for preset in PRESETS:
        section[preset] = _preset_section(preset, cfg, cross_engine)
    return section


def run_advisor() -> Dict:
    """Rank the resiliency knobs on the failure preset the benchmark
    sweeps, under the same repair window (what_if saturates on its own)."""
    rep = what_if("failure_storm",
                  knobs=["elastic_resize", "multi_slice_gang"],
                  n_jobs=TINY["n_jobs"], seed=TINY["seed"],
                  n_pods=TINY["n_pods"], pod_size=TINY["pod_size"],
                  horizon=TINY["horizon_days"] * DAY,
                  size_mix=TINY["size_mix"],
                  slice_repair_s=TINY["slice_repair_s"])
    return {
        "scenario": rep["scenario"],
        "baseline_mpg": round(rep["baseline"]["MPG"], 6),
        "ranking": [{"knob": r["knob"], "targets": r["targets"],
                     "recovered_mpg": round(r["recovered_mpg"], 6),
                     "d_sg": round(r["d_sg"], 6),
                     "d_rg": round(r["d_rg"], 6),
                     "d_pg": round(r["d_pg"], 6)}
                    for r in rep["ranking"]],
    }


def _load_committed() -> Dict:
    if BENCH_PATH.exists():
        return json.loads(BENCH_PATH.read_text())
    return {}


def _write(bench: Dict) -> None:
    bench["version"] = 1
    bench["generated_by"] = "benchmarks/resilience.py"
    bench["peak_rss_mb"] = _peak_rss_mb()
    BENCH_PATH.write_text(json.dumps(bench, indent=1, sort_keys=True) + "\n")


def check(fresh_tiny: Dict, committed: Dict) -> None:
    """CI gate, two-part: (1) elastic must beat rigid on every preset;
    (2) the sim is deterministic, so when the committed baseline ran the
    same config the recovered-MPG values must match exactly."""
    for preset in PRESETS:
        rec = fresh_tiny[preset]["recovered_mpg"]
        if not rec > 0:
            raise SystemExit(
                f"resilience --check FAILED: elastic does not beat rigid "
                f"on {preset} (recovered_mpg={rec})")
    base = committed.get("tiny")
    if not base:
        print("resilience --check: no committed baseline; ordering gate "
              "only")
        return
    if base.get("config_fingerprint") != fresh_tiny["config_fingerprint"]:
        print("resilience --check: tiny config changed; committed baseline "
              "not comparable — skipping exact gate (commit a fresh "
              "BENCH_resilience.json)")
        return
    for preset in PRESETS:
        got = fresh_tiny[preset]["recovered_mpg"]
        want = base[preset]["recovered_mpg"]
        if got != want:
            raise SystemExit(
                f"resilience --check FAILED: {preset} recovered_mpg "
                f"{got} != committed {want} (the sim is deterministic — "
                f"a semantic change must re-bless the baseline)")
    print("resilience --check OK: elastic > rigid on "
          f"{', '.join(PRESETS)}; exact match vs committed baseline")


def main(tiny: bool = False, do_check: bool = False) -> Dict:
    committed = _load_committed()
    bench = dict(committed)
    t_start = time.monotonic()
    fresh_tiny = _scale_section(TINY, cross_engine=True)
    bench["tiny"] = fresh_tiny
    if do_check:
        check(fresh_tiny, committed)
    if not tiny:
        bench["full"] = _scale_section(FULL, cross_engine=False)
        bench["advisor"] = run_advisor()
    _write(bench)
    wall_us = (time.monotonic() - t_start) * 1e6
    derived = {
        "tiny_recovered_storm": bench["tiny"]["failure_storm"]["recovered_mpg"],
        "tiny_recovered_maint": bench["tiny"]["maintenance"]["recovered_mpg"],
    }
    if "full" in bench:
        derived["full_recovered_storm"] = \
            bench["full"]["failure_storm"]["recovered_mpg"]
        derived["full_recovered_maint"] = \
            bench["full"]["maintenance"]["recovered_mpg"]
    print(f"resilience,{wall_us:.1f},{json.dumps(derived, sort_keys=True)}")
    return bench


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: only the tiny rigid-vs-elastic A/B")
    ap.add_argument("--check", action="store_true",
                    help="fail if elastic stops beating rigid, or any "
                         "recovered-MPG drifts from the committed "
                         "BENCH_resilience.json")
    args = ap.parse_args()
    main(tiny=args.tiny, do_check=args.check)
