"""Paper Fig. 16: Scheduling Goodput by job size class, as a *policy sweep*.

Claims reproduced: (1) overall SG > 95% with defragmentation + the
preemption policy; (2) U-shape — XL (protected) and small (quick to place)
jobs see the best SG, medium jobs absorb the evictions.  The ablations are
scheduler policies injected through ``SimConfig`` (fleet.policies), not
bool flags: the paper's best_fit/protect_xl/drain_for_xl combination is
compared against naive placement, unprotected preemption, and no defrag.
"""
from __future__ import annotations

from collections import defaultdict

from benchmarks.common import emit, save_json, timed
from repro.fleet.sim import FleetSim, SimConfig
from repro.fleet.workload import generate_jobs

# (placement, preemption, defrag) policy combinations; first is the paper's
POLICY_SWEEP = [
    ("best_fit", "protect_xl", "drain_for_xl"),
    ("best_fit", "priority_only", "drain_for_xl"),
    ("first_fit", "protect_xl", "migrate_small"),
    ("spread", "priority_only", "none"),
    ("best_fit", "none", "drain_for_xl"),
]


def _one(n_jobs: int, seed: int, placement: str, preemption: str,
         defrag: str):
    cfg = SimConfig(n_pods=16, pod_size=256, horizon=7 * 24 * 3600,
                    seed=seed, retain_intervals=False,
                    placement=placement, preemption=preemption,
                    defrag=defrag)
    sim = FleetSim(cfg)
    # moderate load so queueing reflects topology, not raw shortage
    # production fleets hold headroom for priority work (paper §3.2)
    for j in generate_jobs(n_jobs, cfg.horizon, seed=seed,
                           capacity_chips=cfg.n_pods * cfg.pod_size,
                           target_load=0.5):
        sim.submit(j)
    sim.run()

    # Per paper §4.3: SG's numerator is "all-allocated" time; the per-class
    # losses are gang ASSEMBLY and preemption/failure RESTART gaps (PARTIAL),
    # not the initial queue wait (that is a fleet-capacity matter).  The
    # streaming ledger keeps per-class per-phase sums — no interval list.
    partial = defaultdict(float)
    alloc = defaultdict(float)
    for sc, sums in sim.ledger.segment_phase_chip_time("size_class").items():
        partial[sc] = sums.get("partial", 0.0)
        alloc[sc] = sum(ct for ph, ct in sums.items()
                        if ph not in ("partial", "queued"))
    sg = {s: alloc[s] / (alloc[s] + partial[s])
          for s in sorted(alloc) if alloc[s] + partial[s] > 0}
    overall = (sum(alloc.values())
               / (sum(alloc.values()) + sum(partial.values())))
    return {"sg_by_size": {k: round(v, 4) for k, v in sg.items()},
            "sg_overall": round(overall, 4),
            "preemptions_by_size": _preemptions(sim)}


def run(n_jobs: int = 500, seed: int = 16):
    sweep = {}
    for placement, preemption, defrag in POLICY_SWEEP:
        name = f"{placement}+{preemption}+{defrag}"
        sweep[name] = _one(n_jobs, seed, placement, preemption, defrag)
    paper = sweep["best_fit+protect_xl+drain_for_xl"]
    return {**paper, "policy_sweep": sweep}


def _preemptions(sim):
    out = defaultdict(int)
    for j in sim.jobs.values():
        out[j.spec.size_class] += j.preemptions
    return dict(out)


def main(quick: bool = False):
    res, us = timed(lambda: run(200 if quick else 500))
    save_json("fleet/fig16_sg_by_size.json", res)
    emit("fig16_sg_by_size", us, res)
    return res


if __name__ == "__main__":
    print(main())
