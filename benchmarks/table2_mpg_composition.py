"""Paper Table 2: how optimizing each stack layer moves SG / RG / PG / MPG.

Each row is a fleet-simulator ablation (not hand-typed arithmetic):
  compiler row  -> all jobs' PG x1.2 (faster on-duty steps, device-bound)
  runtime row   -> async checkpointing (off-duty waste down)
  scheduler row -> injected policy combinations (fleet.policies) — the
                   paper's protect_xl/drain_for_xl vs naive spread/none
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, save_json, timed
from repro.fleet.sim import FleetSim, SimConfig
from repro.fleet.workload import generate_jobs


def _sim(seed=2, *, pg_mult=1.0, async_ckpt=False,
         placement="best_fit", preemption="protect_xl",
         defrag="drain_for_xl"):
    cfg = SimConfig(n_pods=8, pod_size=256, horizon=14 * 24 * 3600,
                    seed=seed, retain_intervals=False,
                    placement=placement, preemption=preemption,
                    defrag=defrag)
    sim = FleetSim(cfg)
    for j in generate_jobs(300, cfg.horizon, seed=seed,
                           async_checkpoint=async_ckpt,
                           capacity_chips=cfg.n_pods * cfg.pod_size):
        j = dataclasses.replace(j, pg=min(0.95, j.pg * pg_mult))
        sim.submit(j)
    sim.run()
    return sim.report()


def run(seed: int = 2):
    base = _sim(seed)
    rows = {
        "baseline": base,
        "compiler_step_time_down": _sim(seed, pg_mult=1.2),
        "runtime_offduty_down": _sim(seed, async_ckpt=True),
        "scheduler_policy": base,
        "scheduler_naive": _sim(seed, placement="spread",
                                preemption="priority_only", defrag="none"),
    }
    table = {k: {m: round(v, 4) for m, v in r.as_dict().items()}
             for k, r in rows.items()}
    checks = {
        "compiler_raises_pg_mpg": (
            table["compiler_step_time_down"]["PG"] > table["baseline"]["PG"]
            and table["compiler_step_time_down"]["MPG"]
            > table["baseline"]["MPG"]),
        "runtime_raises_rg_mpg": (
            table["runtime_offduty_down"]["RG"] > table["baseline"]["RG"]
            and table["runtime_offduty_down"]["MPG"]
            > table["baseline"]["MPG"]),
        "policy_beats_naive_mpg": (
            table["scheduler_policy"]["MPG"]
            >= table["scheduler_naive"]["MPG"]),
    }
    return {"table": table, "checks": checks}


def main(quick: bool = False):
    res, us = timed(lambda: run())
    save_json("fleet/table2_mpg_composition.json", res)
    emit("table2_mpg_composition", us, res["checks"])
    return res


if __name__ == "__main__":
    print(main())
