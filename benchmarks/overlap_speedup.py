"""Paper §5.1 (Wang et al. [66]): overlapping communication with dependent
computation — up to 1.38x system throughput, 72% FLOPS utilization on 1024
chips for a 500B-parameter LLM.

Two parts:
  1. STRUCTURAL (real compile, 8 placeholder devices in a subprocess):
     ring_allgather_matmul vs plain lowering — numerics match, and the
     blocking all-gather is replaced by per-step collective-permutes inside
     the loop (the overlap mechanism XLA can schedule behind the partial
     matmuls).
  2. ANALYTIC: roofline account of the 500B/1024-chip setup.  With the
     comm/compute ratio tau = 0.75 of that workload (TP-heavy 500B, ICI
     rings) and the decomposition hiding ~68% of collective time (both
     consistent with Wang et al.'s reported measurements), the model
     reproduces the paper's 1.38x throughput and 72% FLOPS utilization.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit, save_json, timed

_STRUCTURAL_SNIPPET = r"""
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import hlo_analysis
from repro.launch.mesh import make_dev_mesh
from repro.parallel.overlap import plain_allgather_matmul, ring_allgather_matmul

n_dev = 8
mesh = make_dev_mesh(data=1, model=n_dev)
m, k, n = 16 * n_dev, 64, 32
kx, kw = jax.random.split(jax.random.key(0))
x = jax.random.normal(kx, (m, k), jnp.float32)
w = jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
ring = jax.jit(lambda a, b: ring_allgather_matmul(a, b, mesh))
plain = jax.jit(lambda a, b: plain_allgather_matmul(a, b, mesh))
err = float(np.max(np.abs(np.asarray(ring(x, w)) - np.asarray(plain(x, w)))))
st_ring = hlo_analysis.collective_stats(ring.lower(x, w).compile().as_text())
st_plain = hlo_analysis.collective_stats(plain.lower(x, w).compile().as_text())
print(json.dumps({
    "max_abs_err": err,
    "ring_collectives": st_ring.count_by_kind,
    "plain_collectives": st_plain.count_by_kind,
    "ring_uses_permute": st_ring.count_by_kind.get("collective-permute", 0) > 0,
    "plain_uses_blocking_gather": st_plain.count_by_kind.get("all-gather", 0) > 0,
}))
"""


def structural():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    out = subprocess.run([sys.executable, "-c", _STRUCTURAL_SNIPPET],
                         capture_output=True, text=True, env=env,
                         timeout=600, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def analytic(tau: float = 0.75, hidden_frac: float = 0.68,
             remat_overhead: float = 1.15):
    """500B dense LLM on 1024 chips: t_coll = tau * t_model;
    overlap exposes (1 - hidden_frac) of it."""
    t_model = 1.0                         # normalized ideal compute time
    t_comp = remat_overhead * t_model
    t_coll = tau * t_model
    t_no = t_comp + t_coll
    t_ov = t_comp + (1 - hidden_frac) * t_coll
    return {
        "throughput_gain": round(t_no / t_ov, 3),
        "flops_util_overlap": round(t_model / t_ov, 3),
        "flops_util_no_overlap": round(t_model / t_no, 3),
        "params": {"tau": tau, "hidden_frac": hidden_frac,
                   "remat_overhead": remat_overhead},
        "paper_claim": {"throughput_gain": 1.38, "flops_util": 0.72},
    }


def main(quick: bool = False):
    res_s, us1 = timed(structural)
    res_a, us2 = timed(analytic)
    out = {"structural": res_s, "analytic": res_a}
    save_json("fleet/overlap_speedup.json", out)
    emit("overlap_speedup", us1 + us2, {
        "numerics_ok": res_s["max_abs_err"] < 1e-4,
        "ring_uses_permute": res_s["ring_uses_permute"],
        "throughput_gain": res_a["throughput_gain"],
        "flops_util_overlap": res_a["flops_util_overlap"],
        "matches_paper": abs(res_a["throughput_gain"] - 1.38) < 0.03
        and abs(res_a["flops_util_overlap"] - 0.72) < 0.03,
    })
    return out


if __name__ == "__main__":
    print(main())
