"""Streaming-ledger scale proof: a month of multi-cluster fleet time at
>= 5k jobs, accounted WITHOUT materializing the interval list.

Three clusters share one ``GoodputLedger`` (the paper's single fleet-wide
MPG accounting, §4); each simulator streams its events in and the ledger
keeps only O(jobs + segments + windows) accumulator state.  The benchmark
reports the event count vs. the retained-state size — the memory story —
plus the fleet MPG report and the daily SG/RG/PG series, and cross-checks
the streaming totals against a retain-everything control run on the
smallest cluster.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, save_json, timed
from repro.core.attribution import AttributionWaterfall
from repro.core.goodput import compute_goodput
from repro.core.ledger import GoodputLedger
from repro.fleet.sim import FleetSim, SimConfig
from repro.fleet.workload import generate_jobs

DAY = 24 * 3600.0


def run(n_jobs_per_cluster: int = 2000, seed: int = 42):
    horizon = 30 * DAY
    # heterogeneous fleet: three clusters, one shared accounting sink,
    # one attribution waterfall riding the same stream
    ledger = GoodputLedger(window=DAY, retain_intervals=False)
    waterfall = AttributionWaterfall().attach(ledger)
    cluster_shapes = [(8, 256), (16, 256), (4, 256)]
    total_jobs = 0
    for ci, (n_pods, pod_size) in enumerate(cluster_shapes):
        # telemetry cadence is an explicit knob now (was hardcoded
        # horizon/200): 6h samples keep the snapshot cost flat as the
        # horizon grows; sampling never touches the ledger stream
        cfg = SimConfig(n_pods=n_pods, pod_size=pod_size, horizon=horizon,
                        seed=seed + ci, retain_intervals=False,
                        ledger_window=DAY, sample_dt=6 * 3600.0)
        sim = FleetSim(cfg, ledger=ledger)
        for j in generate_jobs(n_jobs_per_cluster, horizon, seed=seed + ci,
                               capacity_chips=n_pods * pod_size,
                               target_load=0.6, pg_table={}):
            # disambiguate job ids across clusters: the shared ledger keys
            # per-job state by id, and every cluster counts from job00000
            j = dataclasses.replace(j, job_id=f"c{ci}/{j.job_id}")
            sim.submit(j)
            total_jobs += 1
        sim.run()

    # attribution must not change the memory story: no interval list
    # materializes, and the waterfall keeps O(#layers x #phases) cells
    assert ledger.intervals is None, "interval list must not materialize"
    wf_state = waterfall.state_size()
    assert sum(wf_state.values()) < 100, (
        f"attribution state must stay O(layers x phases): {wf_state}")
    waterfall.assert_conserves(ledger)
    rep = ledger.report()
    state = ledger.state_size()
    series = ledger.series(
        capacity_chips=sum(n * p for n, p in cluster_shapes))

    # equivalence control: smallest cluster re-run with retention; the
    # batch compute_goodput over its list must match its streaming report
    ctl_cfg = SimConfig(n_pods=4, pod_size=256, horizon=horizon,
                        seed=seed + 2, ledger_window=DAY,
                        sample_dt=6 * 3600.0)
    ctl = FleetSim(ctl_cfg)
    for j in generate_jobs(n_jobs_per_cluster, horizon, seed=seed + 2,
                           capacity_chips=4 * 256, target_load=0.6,
                           pg_table={}):
        ctl.submit(j)
    ctl.run()
    batch = compute_goodput(ctl.intervals, ctl.capacity_chip_time,
                            ctl.pg_by_job())
    stream = ctl.report()
    drift = max(abs(batch.sg - stream.sg), abs(batch.rg - stream.rg),
                abs(batch.pg - stream.pg))

    return {
        "jobs": total_jobs,
        "clusters": len(cluster_shapes),
        "horizon_days": horizon / DAY,
        "events_streamed": ledger.n_events,
        "retained_state_entries": sum(state.values()),
        "state_size": state,
        "events_per_state_entry": round(
            ledger.n_events / max(1, sum(state.values())), 1),
        "mpg": {k: round(v, 4) for k, v in rep.as_dict().items()},
        "daily_windows": len(series),
        "stream_vs_batch_max_drift": drift,
        "attribution": {
            "state_entries": sum(wf_state.values()),
            "conserved": waterfall.conservation()["conserved"],
            "lost_by_layer": {
                k: round(v / rep.capacity_chip_time, 4)
                for k, v in waterfall.report()["lost_by_layer"].items()},
        },
    }


def main(quick: bool = False):
    res, us = timed(lambda: run(700 if quick else 2000))
    save_json("fleet/ledger_scale.json", res)
    emit("ledger_scale", us, {
        "jobs": res["jobs"],
        "events_streamed": res["events_streamed"],
        "retained_state_entries": res["retained_state_entries"],
        "events_per_state_entry": res["events_per_state_entry"],
        "mpg": res["mpg"]["MPG"],
        "drift": res["stream_vs_batch_max_drift"],
    })
    return res


if __name__ == "__main__":
    print(main())
