"""Paged-decode microbenchmark: batch width x sequence length sweep of
per-slot batch-1 decode vs one batched paged-attention decode step
(``results/serve/paged_decode.json``).

Isolates the decode step itself — no engine, no admission — so the
numbers answer exactly one question: at width ``w`` and resident length
``s``, what does replacing ``w`` batch-1 ``decode_step`` dispatches with
ONE ``paged_decode_step`` at width ``w`` buy?  Both arms are jitted
once per sweep point and timed over a data-dependent call chain
(each step's argmax token feeds the next) with a single device sync at
the end, mirroring the serving loop's one-sync-per-iteration contract.

``attn_impl="ref"`` (the XLA gather path) keeps the sweep honest on
CPU; the Pallas kernel's interpret mode is a correctness vehicle, not a
performance one, and on TPU ``attn_impl="auto"`` selects the kernel.

Needs JAX; prints a skip note and writes nothing when it is missing
(the numpy-only benchmark CI jobs).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

from benchmarks.common import emit, save_json

ARCH = "smollm-135m"

QUICK = {"widths": [1, 4], "seq_lens": [128], "iters": 10}
FULL = {"widths": [1, 2, 4, 8], "seq_lens": [128, 256], "iters": 30}


def _sweep_point(cfg, params, width: int, seq_len: int, iters: int) -> Dict:
    import jax
    import jax.numpy as jnp

    from repro.models import model, transformer
    from repro.serve.kv_cache import FLASH_ATTENTION_BLOCK_K

    bt = FLASH_ATTENTION_BLOCK_K
    nb = -(-seq_len // bt)
    tok0 = jnp.zeros((width,), jnp.int32)

    # --- per-slot arm: width sequential batch-1 decode dispatches -------
    dec = jax.jit(model.decode_fn(cfg))
    caches = []
    for _ in range(width):
        c = model.init_cache(cfg, 1, seq_len)
        c["pos"] = jnp.full((1,), seq_len - 1, jnp.int32)
        caches.append(c)

    def per_slot_round(toks):
        out = []
        for i in range(width):
            logits, caches[i] = dec(params, toks[i][None], caches[i])
            out.append(jnp.argmax(logits, -1)[0].astype(jnp.int32))
        return jnp.stack(out)

    toks = tok0
    per_slot_round(toks)                      # compile
    jax.block_until_ready(toks)
    t0 = time.perf_counter()
    for _ in range(iters):
        toks = per_slot_round(toks)
    jax.block_until_ready(toks)
    per_slot_s = time.perf_counter() - t0

    # --- batched arm: one paged decode step at full width ---------------
    n_pages = width * nb + 1
    kp = jnp.zeros(transformer.paged_kv_shape(cfg, n_pages, bt),
                   cfg.compute_dtype)
    vp = jnp.zeros_like(kp)
    tables = jnp.arange(width * nb, dtype=jnp.int32).reshape(width, nb)
    lens = jnp.full((width,), seq_len, jnp.int32)
    step = jax.jit(
        lambda p, t, ln, k, v, b: model.paged_decode_fn(
            cfg, attn_impl="ref")(p, t, ln, k, v, b),
        donate_argnums=(3, 4))

    def batched_round(toks, kp, vp):
        logits, kp, vp = step(params, toks, lens, kp, vp, tables)
        return jnp.argmax(logits, -1).astype(jnp.int32), kp, vp

    toks, kp, vp = batched_round(tok0, kp, vp)     # compile
    jax.block_until_ready(toks)
    t0 = time.perf_counter()
    for _ in range(iters):
        toks, kp, vp = batched_round(toks, kp, vp)
    jax.block_until_ready(toks)
    batched_s = time.perf_counter() - t0

    per_tps = width * iters / max(per_slot_s, 1e-12)
    bat_tps = width * iters / max(batched_s, 1e-12)
    return {
        "width": width, "seq_len": seq_len, "iters": iters,
        "per_slot_tokens_per_s": round(per_tps, 1),
        "batched_tokens_per_s": round(bat_tps, 1),
        "ratio": round(bat_tps / max(per_tps, 1e-12), 3),
    }


def main(quick: bool = True) -> Dict:
    try:
        import jax  # noqa: F401
    except ModuleNotFoundError:
        print("paged_decode,0.0,"
              '{"skipped": "jax unavailable in this environment"}')
        return {}
    from repro.configs import get_smoke
    from repro.models import model
    from repro.serve.kv_cache import FLASH_ATTENTION_BLOCK_K

    preset = QUICK if quick else FULL
    cfg = get_smoke(ARCH)
    params = model.init_params(cfg, jax.random.key(0))
    t_start = time.monotonic()
    sweep: List[Dict] = []
    for s in preset["seq_lens"]:
        for w in preset["widths"]:
            sweep.append(_sweep_point(cfg, params, w, s, preset["iters"]))
    wide = [p for p in sweep if p["width"] >= 4]
    out = {
        "arch": f"{ARCH} (smoke)",
        "attn_impl": "ref",
        "block_tokens": FLASH_ATTENTION_BLOCK_K,
        "quick": quick,
        "sweep": sweep,
        "checks": {
            "n_points": len(sweep),
            "batched_wins_at_width_ge_4":
                bool(wide) and all(p["ratio"] > 1.0 for p in wide),
        },
    }
    save_json("serve/paged_decode.json", out)
    wall_us = (time.monotonic() - t_start) * 1e6
    emit("paged_decode", wall_us, {
        "n_points": len(sweep),
        "max_ratio": max(p["ratio"] for p in sweep),
        "batched_wins_at_width_ge_4":
            out["checks"]["batched_wins_at_width_ge_4"],
    })
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="full width x seq-length sweep (slower)")
    args = ap.parse_args()
    main(quick=not args.full)
