"""Serve-scale benchmark: continuous vs static batching at equal capacity
(``BENCH_serve.json`` + ``results/serve/serve_scale.json``).

Drives :class:`repro.serve.ContinuousServeEngine` and its equal-capacity
static reference (``repro.serve.run_static``) with the *simulated*
executor over scenario arrival processes
(``repro.fleet.scenarios.request_arrivals`` — the same diurnal/bursty
modulations the fleet simulator uses), so the whole run is virtual-time,
deterministic, and numpy-free-importable for the benchmark CI jobs.

Sections:

  * ``tiny`` — seconds-long bursty run under BOTH engines; CI runs only
    this (``--tiny``) and ``--check`` gates on the ordering invariant
    (continuous delivers MORE tokens within SLO than static at equal
    capacity) plus a regression floor on the continuous engine's
    SLO-token-goodput margin vs the committed baseline;
  * ``diurnal`` / ``bursty`` — large-request-count runs (the paper's
    fluctuating-demand serving story, Fig. 15): p50/p99 TTFT and
    per-token latency alongside SG/RG/PG and SLO-goodput for both
    engines.

Every section records a config fingerprint so numbers are never compared
across silently different workloads.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import time
from typing import Dict

from repro.fleet.scenarios import SCENARIOS, request_arrivals
from repro.serve import (ContinuousServeEngine, ServeSLO, SimulatedExecutor,
                         run_static, synthetic_requests)

from benchmarks.common import save_json

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_serve.json"

# CI regression gate: fail when the fresh tiny-section SLO-token-goodput
# *margin* (continuous - static) drops below this fraction of the
# committed baseline's margin
REGRESSION_FLOOR = 0.5

TINY = {"requests": 400, "span": 120.0, "n_slots": 4, "arrival": "bursty",
        "prompt_len": 96, "max_new": [8, 48], "slo_ttft": 1.0,
        "slo_tpot": 0.05, "seed": 42}
# ~16 slots x ~670 tok/s serving ~800k tokens over 25 virtual minutes:
# load ~0.8, where scheduling policy is what separates the engines
FULL = {"requests": 20_000, "span": 1500.0, "n_slots": 16,
        "prompt_len": 128, "max_new": [16, 64], "slo_ttft": 1.0,
        "slo_tpot": 0.05, "seed": 42}
# same load point at 1/10 the population for `benchmarks.run` quick mode
QUICK = dict(FULL, requests=2_000, span=150.0)


def _fingerprint(cfg: Dict) -> str:
    return hashlib.sha256(
        json.dumps(cfg, sort_keys=True).encode()).hexdigest()[:16]


def _requests(cfg: Dict, arrival: str):
    arr = request_arrivals(cfg["requests"], cfg["span"], seed=cfg["seed"],
                           arrival=SCENARIOS[arrival].arrival)
    return synthetic_requests(arr, prompt_len=cfg["prompt_len"],
                              max_new=tuple(cfg["max_new"]),
                              seed=cfg["seed"])


def _engine_dict(report, wall_s: float) -> Dict:
    out = report.as_dict()
    out["bench_wall_s"] = round(wall_s, 3)
    out["tokens_per_virtual_s"] = (round(report.tokens / report.span, 1)
                                   if report.span else 0.0)
    return out


def run_section(cfg: Dict, arrival: str) -> Dict:
    """Both engines over the identical request stream and SLO."""
    slo = ServeSLO(ttft=cfg["slo_ttft"], tpot=cfg["slo_tpot"])
    t0 = time.perf_counter()
    cont = ContinuousServeEngine(cfg["n_slots"], SimulatedExecutor(),
                                 slo=slo).run(_requests(cfg, arrival))
    wall_c = time.perf_counter() - t0
    t0 = time.perf_counter()
    stat = run_static(_requests(cfg, arrival), batch=cfg["n_slots"],
                      executor=SimulatedExecutor(), slo=slo)
    wall_s = time.perf_counter() - t0
    assert cont.tokens == stat.tokens, "engines must deliver equal work"
    full_cfg = dict(cfg, arrival=arrival)
    return {
        "config": full_cfg,
        "config_fingerprint": _fingerprint(full_cfg),
        "continuous": _engine_dict(cont, wall_c),
        "static": _engine_dict(stat, wall_s),
        "slo_tokens_margin": cont.tokens_within_slo - stat.tokens_within_slo,
        "slo_token_goodput_margin": round(
            cont.slo_token_goodput - stat.slo_token_goodput, 6),
    }


def _load_committed() -> Dict:
    if BENCH_PATH.exists():
        return json.loads(BENCH_PATH.read_text())
    return {}


def check(fresh_tiny: Dict, committed: Dict) -> None:
    """CI gate: (1) the ordering invariant — continuous must beat static
    on tokens delivered within SLO at equal capacity; (2) the margin must
    not collapse vs the committed baseline."""
    margin = fresh_tiny["slo_tokens_margin"]
    if margin <= 0:
        raise SystemExit(
            f"serve_scale --check FAILED: continuous does not beat static "
            f"on within-SLO tokens (margin {margin})")
    base = committed.get("tiny")
    if not base:
        print("serve_scale --check: no committed baseline; ordering "
              "invariant OK, skipping margin gate")
        return
    if base.get("config_fingerprint") != fresh_tiny["config_fingerprint"]:
        print("serve_scale --check: tiny config changed; committed "
              "baseline not comparable — skipping margin gate (commit a "
              "fresh BENCH_serve.json)")
        return
    floor = base["slo_token_goodput_margin"] * REGRESSION_FLOOR
    fresh = fresh_tiny["slo_token_goodput_margin"]
    msg = (f"tiny SLO-goodput margin {fresh:.4f} vs committed "
           f"{base['slo_token_goodput_margin']:.4f} (floor {floor:.4f})")
    if fresh < floor:
        raise SystemExit(f"serve_scale --check FAILED: {msg}")
    print(f"serve_scale --check OK: {msg}")


def main(quick: bool = False, tiny: bool = False,
         do_check: bool = False) -> Dict:
    committed = _load_committed()
    bench = dict(committed)
    t_start = time.monotonic()
    fresh_tiny = run_section(TINY, TINY["arrival"])
    bench["tiny"] = fresh_tiny
    if do_check:
        check(fresh_tiny, committed)
    sections = {"tiny": fresh_tiny}
    if not tiny:
        cfg = QUICK if quick else FULL
        for arrival in ("diurnal", "bursty"):
            sections[arrival] = bench[arrival] = run_section(cfg, arrival)
    bench["version"] = 1
    bench["generated_by"] = "benchmarks/serve_scale.py"
    BENCH_PATH.write_text(json.dumps(bench, indent=1, sort_keys=True) + "\n")
    save_json("serve/serve_scale.json", sections)
    wall_us = (time.monotonic() - t_start) * 1e6
    derived = {
        "tiny_slo_margin": fresh_tiny["slo_tokens_margin"],
        "tiny_continuous_slo_goodput":
            fresh_tiny["continuous"]["slo_token_goodput"],
    }
    if "bursty" in sections:
        derived["bursty_slo_margin"] = \
            sections["bursty"]["slo_tokens_margin"]
        derived["bursty_p99_ttft_continuous"] = \
            sections["bursty"]["continuous"]["ttft_s"]["p99"]
    print(f"serve_scale,{wall_us:.1f},{json.dumps(derived, sort_keys=True)}")
    return bench


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: only the tiny A/B section")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale request populations (slower)")
    ap.add_argument("--check", action="store_true",
                    help="fail if continuous stops beating static on "
                         "within-SLO tokens, or the margin regressed vs "
                         "the committed BENCH_serve.json")
    args = ap.parse_args()
    main(quick=not args.full, tiny=args.tiny, do_check=args.check)
