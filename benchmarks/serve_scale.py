"""Serve-scale benchmark: continuous vs static batching at equal capacity
(``BENCH_serve.json`` + ``results/serve/serve_scale.json``).

Drives :class:`repro.serve.ContinuousServeEngine` and its equal-capacity
static reference (``repro.serve.run_static``) with the *simulated*
executor over scenario arrival processes
(``repro.fleet.scenarios.request_arrivals`` — the same diurnal/bursty
modulations the fleet simulator uses), so the whole run is virtual-time,
deterministic, and numpy-free-importable for the benchmark CI jobs.

Sections:

  * ``tiny`` — seconds-long bursty run under BOTH engines; CI runs only
    this (``--tiny``) and ``--check`` gates on the ordering invariant
    (continuous delivers MORE tokens within SLO than static at equal
    capacity) plus a regression floor on the continuous engine's
    SLO-token-goodput margin vs the committed baseline;
  * ``diurnal`` / ``bursty`` — large-request-count runs (the paper's
    fluctuating-demand serving story, Fig. 15): p50/p99 TTFT and
    per-token latency alongside SG/RG/PG and SLO-goodput for both
    engines;
  * ``batched_tiny`` / ``batched_full`` — the *real-model* batched
    paged-decode A/B: the same continuous engine drives
    ``JaxBatchedExecutor`` (one jitted decode at fixed width over the
    allocator's block tables) vs ``JaxSlotExecutor`` (per-slot batch-1)
    over an identical request stream, asserts token identity, and
    records decode tokens/s for each arm plus their ratio.  These
    sections need JAX; when it is not importable (the numpy-only
    benchmark CI job) the committed sections are preserved untouched and
    ``--check`` gates on them structurally.

Every section records a config fingerprint so numbers are never compared
across silently different workloads.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys
import time
from typing import Dict, Optional

from repro.fleet.scenarios import SCENARIOS, request_arrivals
from repro.serve import (ContinuousServeEngine, ServeSLO, SimulatedExecutor,
                         run_static, synthetic_requests)
from repro.serve.engine import NO_SLO, ServeRequest

from benchmarks.common import save_json

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_serve.json"

# CI regression gate: fail when the fresh tiny-section SLO-token-goodput
# *margin* (continuous - static) drops below this fraction of the
# committed baseline's margin
REGRESSION_FLOOR = 0.5

TINY = {"requests": 400, "span": 120.0, "n_slots": 4, "arrival": "bursty",
        "prompt_len": 96, "max_new": [8, 48], "slo_ttft": 1.0,
        "slo_tpot": 0.05, "seed": 42}
# ~16 slots x ~670 tok/s serving ~800k tokens over 25 virtual minutes:
# load ~0.8, where scheduling policy is what separates the engines
FULL = {"requests": 20_000, "span": 1500.0, "n_slots": 16,
        "prompt_len": 128, "max_new": [16, 64], "slo_ttft": 1.0,
        "slo_tpot": 0.05, "seed": 42}
# same load point at 1/10 the population for `benchmarks.run` quick mode
QUICK = dict(FULL, requests=2_000, span=150.0)

# real-model batched paged-decode A/B (needs JAX; attn_impl="ref" is the
# XLA gather path — the Pallas kernel's interpret mode is a correctness
# vehicle, not a CPU performance one).  Prompt lengths come from a small
# discrete set so the per-length prefill jit cache stays bounded; the
# *decode* side is what the section measures, and both executors decode
# at a single compiled shape.
BATCHED_TINY = {"arch": "smollm-135m", "requests": 24, "n_slots": 4,
                "max_len": 64, "prompt_lens": [4, 8, 12, 16],
                "max_new": [4, 16], "attn_impl": "ref", "seed": 42}
BATCHED_FULL = dict(BATCHED_TINY, requests=128, n_slots=8, max_len=96,
                    prompt_lens=[8, 16, 32, 48], max_new=[8, 32])


def _fingerprint(cfg: Dict) -> str:
    return hashlib.sha256(
        json.dumps(cfg, sort_keys=True).encode()).hexdigest()[:16]


def _requests(cfg: Dict, arrival: str):
    arr = request_arrivals(cfg["requests"], cfg["span"], seed=cfg["seed"],
                           arrival=SCENARIOS[arrival].arrival)
    return synthetic_requests(arr, prompt_len=cfg["prompt_len"],
                              max_new=tuple(cfg["max_new"]),
                              seed=cfg["seed"])


def _engine_dict(report, wall_s: float) -> Dict:
    out = report.as_dict()
    out["bench_wall_s"] = round(wall_s, 3)
    out["tokens_per_virtual_s"] = (round(report.tokens / report.span, 1)
                                   if report.span else 0.0)
    return out


def run_section(cfg: Dict, arrival: str) -> Dict:
    """Both engines over the identical request stream and SLO."""
    slo = ServeSLO(ttft=cfg["slo_ttft"], tpot=cfg["slo_tpot"])
    t0 = time.perf_counter()
    cont = ContinuousServeEngine(cfg["n_slots"], SimulatedExecutor(),
                                 slo=slo).run(_requests(cfg, arrival))
    wall_c = time.perf_counter() - t0
    t0 = time.perf_counter()
    stat = run_static(_requests(cfg, arrival), batch=cfg["n_slots"],
                      executor=SimulatedExecutor(), slo=slo)
    wall_s = time.perf_counter() - t0
    assert cont.tokens == stat.tokens, "engines must deliver equal work"
    full_cfg = dict(cfg, arrival=arrival)
    return {
        "config": full_cfg,
        "config_fingerprint": _fingerprint(full_cfg),
        "continuous": _engine_dict(cont, wall_c),
        "static": _engine_dict(stat, wall_s),
        "slo_tokens_margin": cont.tokens_within_slo - stat.tokens_within_slo,
        "slo_token_goodput_margin": round(
            cont.slo_token_goodput - stat.slo_token_goodput, 6),
    }


# ---------------------------------------------------------------------------
# real-model batched paged-decode A/B
# ---------------------------------------------------------------------------

def _batched_requests(cfg: Dict, model_cfg, seed: int):
    import numpy as np

    rng = np.random.default_rng(seed)
    nlo, nhi = cfg["max_new"]
    reqs = []
    for i in range(cfg["requests"]):
        plen = int(rng.choice(cfg["prompt_lens"]))
        reqs.append(ServeRequest(
            rid=i, prompt_len=plen, max_new=int(rng.integers(nlo, nhi + 1)),
            t_submit=0.0,
            prompt=rng.integers(0, model_cfg.vocab_size, plen)
            .astype(np.int32)))
    return reqs


def _instrument_decode(ex) -> Dict:
    """Wrap ``ex.decode`` to accumulate executor-measured decode cost and
    token count — the engine-agnostic source of decode tokens/s."""
    stats = {"decode_tokens": 0, "decode_s": 0.0, "decode_calls": 0}
    orig = ex.decode

    def decode(reqs):
        toks, cost = orig(reqs)
        stats["decode_tokens"] += len(toks)
        stats["decode_s"] += cost
        stats["decode_calls"] += 1
        return toks, cost

    ex.decode = decode
    return stats


def run_batched_section(cfg: Dict) -> Dict:
    """Batched paged-decode vs per-slot batch-1 decode, same continuous
    engine, identical request stream: token identity asserted, decode
    tokens/s measured off the executors' own cost clocks."""
    from repro.configs import get_smoke
    from repro.serve.batched_executor import JaxBatchedExecutor
    from repro.serve.jax_executor import JaxSlotExecutor

    mcfg = get_smoke(cfg["arch"])
    n_slots, max_len = cfg["n_slots"], cfg["max_len"]

    def run_arm(ex):
        kv = getattr(ex, "kv", None)
        # warmup run compiles every jitted path at the serving width
        warm = _batched_requests(cfg, mcfg, cfg["seed"] + 1)[: 2 * n_slots]
        ContinuousServeEngine(n_slots, ex, slo=NO_SLO, kv_cache=kv).run(warm)
        stats = _instrument_decode(ex)
        reqs = _batched_requests(cfg, mcfg, cfg["seed"])
        t0 = time.perf_counter()
        rep = ContinuousServeEngine(n_slots, ex, slo=NO_SLO,
                                    kv_cache=kv).run(reqs)
        wall = time.perf_counter() - t0
        toks = {r.rid: list(r.out_tokens) for r in reqs}
        tps = stats["decode_tokens"] / max(stats["decode_s"], 1e-12)
        row = {
            "decode_tokens": stats["decode_tokens"],
            "decode_s": round(stats["decode_s"], 6),
            "decode_calls": stats["decode_calls"],
            "decode_tokens_per_s": round(tps, 1),
            "tokens": rep.tokens,
            "requests": rep.requests,
            "bench_wall_s": round(wall, 3),
        }
        return row, toks

    per_row, per_toks = run_arm(JaxSlotExecutor(mcfg, max_len))
    per_row["executor"] = "JaxSlotExecutor"
    bat_ex = JaxBatchedExecutor(mcfg, max_len, n_slots,
                                attn_impl=cfg["attn_impl"])
    bat_row, bat_toks = run_arm(bat_ex)
    bat_row["executor"] = "JaxBatchedExecutor"
    bat_row["decode_compiles"] = bat_ex.decode_compiles()
    bat_row["kv_cache"] = bat_ex.kv.stats.as_dict()
    identical = per_toks == bat_toks
    assert identical, "batched decode diverged from per-slot tokens"
    ratio = (bat_row["decode_tokens_per_s"]
             / max(per_row["decode_tokens_per_s"], 1e-12))
    return {
        "config": dict(cfg),
        "config_fingerprint": _fingerprint(cfg),
        "per_slot": per_row,
        "batched": bat_row,
        "decode_tokens_per_s_ratio": round(ratio, 3),
        "tokens_identical": identical,
    }


def _maybe_batched_section(cfg: Dict) -> Optional[Dict]:
    try:
        import jax  # noqa: F401
    except ModuleNotFoundError:
        print("serve_scale: jax unavailable — batched sections kept from "
              "the committed BENCH_serve.json", file=sys.stderr)
        return None
    return run_batched_section(cfg)


def _load_committed() -> Dict:
    if BENCH_PATH.exists():
        return json.loads(BENCH_PATH.read_text())
    return {}


def check(fresh_tiny: Dict, committed: Dict,
          fresh_batched: Optional[Dict] = None) -> None:
    """CI gate: (1) the ordering invariant — continuous must beat static
    on tokens delivered within SLO at equal capacity; (2) the margin must
    not collapse vs the committed baseline; (3) the committed batched
    paged-decode sections must stay token-identical with a batching win
    (decode tokens/s ratio > 1) at width >= 4; (4) a freshly-run batched
    section (JAX available) must be token-identical with exactly one
    decode compile."""
    margin = fresh_tiny["slo_tokens_margin"]
    if margin <= 0:
        raise SystemExit(
            f"serve_scale --check FAILED: continuous does not beat static "
            f"on within-SLO tokens (margin {margin})")
    _check_batched(committed, fresh_batched)
    base = committed.get("tiny")
    if not base:
        print("serve_scale --check: no committed baseline; ordering "
              "invariant OK, skipping margin gate")
        return
    if base.get("config_fingerprint") != fresh_tiny["config_fingerprint"]:
        print("serve_scale --check: tiny config changed; committed "
              "baseline not comparable — skipping margin gate (commit a "
              "fresh BENCH_serve.json)")
        return
    floor = base["slo_token_goodput_margin"] * REGRESSION_FLOOR
    fresh = fresh_tiny["slo_token_goodput_margin"]
    msg = (f"tiny SLO-goodput margin {fresh:.4f} vs committed "
           f"{base['slo_token_goodput_margin']:.4f} (floor {floor:.4f})")
    if fresh < floor:
        raise SystemExit(f"serve_scale --check FAILED: {msg}")
    print(f"serve_scale --check OK: {msg}")


def _check_batched(committed: Dict, fresh_batched: Optional[Dict]) -> None:
    """Structural gates on the committed batched sections (no JAX needed)
    plus determinism/compile gates on a fresh run when JAX is present.
    The fresh gates avoid wall-clock ratio thresholds — CI runner timing
    is noisy — and pin what must be exact: token identity and the single
    decode compile."""
    for name, sec in sorted(committed.items()):
        if not (isinstance(sec, dict) and "decode_tokens_per_s_ratio" in sec):
            continue
        if sec.get("tokens_identical") is not True:
            raise SystemExit(
                f"serve_scale --check FAILED: committed {name} is not "
                "token-identical between batched and per-slot")
        ratio = sec["decode_tokens_per_s_ratio"]
        if sec["config"]["n_slots"] >= 4 and ratio <= 1.0:
            raise SystemExit(
                f"serve_scale --check FAILED: committed {name} shows no "
                f"batching win (decode tokens/s ratio {ratio} at width "
                f"{sec['config']['n_slots']})")
        print(f"serve_scale --check OK: committed {name} ratio {ratio} "
              f"at width {sec['config']['n_slots']}")
    if fresh_batched is None:
        return
    if fresh_batched["tokens_identical"] is not True:
        raise SystemExit("serve_scale --check FAILED: fresh batched run "
                         "is not token-identical to per-slot")
    compiles = fresh_batched["batched"]["decode_compiles"]
    if compiles != 1:
        raise SystemExit(
            f"serve_scale --check FAILED: batched decode compiled "
            f"{compiles} times (admission/detach must not recompile)")
    print(f"serve_scale --check OK: fresh batched_tiny token-identical, "
          f"1 decode compile, ratio "
          f"{fresh_batched['decode_tokens_per_s_ratio']}")


def main(quick: bool = False, tiny: bool = False,
         do_check: bool = False) -> Dict:
    committed = _load_committed()
    bench = dict(committed)
    t_start = time.monotonic()
    fresh_tiny = run_section(TINY, TINY["arrival"])
    bench["tiny"] = fresh_tiny
    sections = {"tiny": fresh_tiny}
    fresh_batched = _maybe_batched_section(BATCHED_TINY)
    if fresh_batched is not None:
        sections["batched_tiny"] = bench["batched_tiny"] = fresh_batched
    if do_check:
        check(fresh_tiny, committed, fresh_batched)
    if not tiny:
        cfg = QUICK if quick else FULL
        for arrival in ("diurnal", "bursty"):
            sections[arrival] = bench[arrival] = run_section(cfg, arrival)
        if fresh_batched is not None:
            sections["batched_full"] = bench["batched_full"] = \
                run_batched_section(BATCHED_FULL)
    bench["version"] = 1
    bench["generated_by"] = "benchmarks/serve_scale.py"
    BENCH_PATH.write_text(json.dumps(bench, indent=1, sort_keys=True) + "\n")
    save_json("serve/serve_scale.json", sections)
    wall_us = (time.monotonic() - t_start) * 1e6
    derived = {
        "tiny_slo_margin": fresh_tiny["slo_tokens_margin"],
        "tiny_continuous_slo_goodput":
            fresh_tiny["continuous"]["slo_token_goodput"],
    }
    if "bursty" in sections:
        derived["bursty_slo_margin"] = \
            sections["bursty"]["slo_tokens_margin"]
        derived["bursty_p99_ttft_continuous"] = \
            sections["bursty"]["continuous"]["ttft_s"]["p99"]
    for name in ("batched_tiny", "batched_full"):
        if name in sections:
            derived[f"{name}_decode_tps_ratio"] = \
                sections[name]["decode_tokens_per_s_ratio"]
    print(f"serve_scale,{wall_us:.1f},{json.dumps(derived, sort_keys=True)}")
    return bench


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: only the tiny A/B section")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale request populations (slower)")
    ap.add_argument("--check", action="store_true",
                    help="fail if continuous stops beating static on "
                         "within-SLO tokens, or the margin regressed vs "
                         "the committed BENCH_serve.json")
    args = ap.parse_args()
    main(quick=not args.full, tiny=args.tiny, do_check=args.check)
