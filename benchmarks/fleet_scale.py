"""Fleet-scale perf benchmark: the repo's speed bar (BENCH_fleet_scale.json).

Three sections, all written to ``BENCH_fleet_scale.json`` at the repo
root so every later PR is held to the same trajectory:

  * ``tiny`` — a seconds-long smoke config run under BOTH engines; CI
    runs only this (``--tiny``) and gates on the *speedup ratio*
    (vectorized vs reference on the same machine), which is portable
    across runner hardware where raw events/sec is not;
  * ``ledger_scale_config`` — the pre-existing ``benchmarks/ledger_scale``
    workload (3 clusters, 30 days, shared ledger + attribution
    waterfall) timed head-to-head under both engines;
  * ``year_scale`` — 1M jobs over a simulated year on 3 clusters under
    the vectorized engine (events/sec, wall-clock, peak RSS).

Every section also records a config fingerprint (sha256 over the exact
knobs) so a number is never compared against a silently different
workload.  ``--check`` re-runs the tiny section and fails if its speedup
ratio fell below ``REGRESSION_FLOOR`` x the committed baseline.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import pathlib
import resource
import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.core.attribution import AttributionWaterfall
from repro.core.ledger import GoodputLedger
from repro.fleet.sim import FleetSim, SimConfig
from repro.fleet.workload import generate_jobs

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_fleet_scale.json"
DAY = 24 * 3600.0
YEAR = 365 * DAY

# CI regression gate: fail when the fresh tiny-section speedup drops
# below this fraction of the committed baseline's (>30% regression)
REGRESSION_FLOOR = 0.7

# the year-scale size mix excludes XL (multi-pod) jobs: their drain/
# defragmentation migration churn is a *scheduling* stress (covered by
# ledger_scale_config), not a throughput benchmark — with XL in the mix
# the event count stops being O(jobs) and the run measures churn instead
YEAR_MIX = {"small": 0.5, "medium": 0.35, "large": 0.15, "xl": 0.0}

TINY = {"jobs_per_cluster": 150, "horizon_days": 7.0, "target_load": 0.6,
        "clusters": [[4, 256], [2, 256]], "seed": 42}
LEDGER_SCALE = {"jobs_per_cluster": 700, "horizon_days": 30.0,
                "target_load": 0.6,
                "clusters": [[8, 256], [16, 256], [4, 256]], "seed": 42}
YEAR_SCALE = {"jobs_per_cluster": 333_334, "horizon_days": 365.0,
              "target_load": 0.5,
              "clusters": [[32, 256], [32, 256], [32, 256]], "seed": 42,
              "size_mix": YEAR_MIX}


def _fingerprint(cfg: Dict) -> str:
    return hashlib.sha256(
        json.dumps(cfg, sort_keys=True).encode()).hexdigest()[:16]


def _peak_rss_mb() -> float:
    # ru_maxrss is KiB on Linux, bytes on macOS
    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":
        peak /= 1024
    return round(peak / 1024, 1)


def _run_fleet(cfg: Dict, engine: str,
               size_mix: Optional[Dict[str, float]] = None
               ) -> Tuple[float, int, int]:
    """Simulate the config's clusters into one shared ledger (with an
    attribution waterfall riding the stream, like ledger_scale does);
    returns (sim wall-clock seconds, events, jobs)."""
    horizon = cfg["horizon_days"] * DAY
    seed = cfg["seed"]
    ledger = GoodputLedger(window=DAY, retain_intervals=False)
    waterfall = AttributionWaterfall().attach(ledger)
    total_jobs = 0
    wall = 0.0
    for ci, (n_pods, pod_size) in enumerate(cfg["clusters"]):
        sim_cfg = SimConfig(n_pods=n_pods, pod_size=pod_size,
                            horizon=horizon, seed=seed + ci,
                            retain_intervals=False, ledger_window=DAY,
                            sample_dt=6 * 3600.0, engine=engine)
        sim = FleetSim(sim_cfg, ledger=ledger)
        for j in generate_jobs(cfg["jobs_per_cluster"], horizon,
                               seed=seed + ci,
                               capacity_chips=n_pods * pod_size,
                               target_load=cfg["target_load"],
                               size_mix=size_mix, pg_table={}):
            sim.submit(dataclasses.replace(j, job_id=f"c{ci}/{j.job_id}"))
            total_jobs += 1
        t0 = time.perf_counter()
        sim.run()
        wall += time.perf_counter() - t0
    waterfall.assert_conserves(ledger)
    return wall, ledger.n_events, total_jobs


def _ab_section(cfg: Dict, size_mix: Optional[Dict[str, float]] = None
                ) -> Dict:
    """Both engines on the same config; the reference run doubles as the
    equivalence cross-check (identical event counts by construction)."""
    wall_v, events_v, jobs = _run_fleet(cfg, "vectorized", size_mix)
    wall_r, events_r, _ = _run_fleet(cfg, "reference", size_mix)
    assert events_v == events_r, (
        f"engines disagree on event count: {events_v} != {events_r}")
    return {
        "config": cfg,
        "config_fingerprint": _fingerprint(cfg),
        "jobs": jobs,
        "events": events_v,
        "vectorized": {"wall_s": round(wall_v, 3),
                       "events_per_s": round(events_v / wall_v, 1)},
        "reference": {"wall_s": round(wall_r, 3),
                      "events_per_s": round(events_r / wall_r, 1)},
        "speedup": round(wall_r / wall_v, 3),
    }


def run_tiny() -> Dict:
    return _ab_section(TINY)


def run_ledger_scale_config() -> Dict:
    return _ab_section(LEDGER_SCALE)


def run_year_scale() -> Dict:
    cfg = dict(YEAR_SCALE)
    mix = cfg.pop("size_mix")
    wall, events, jobs = _run_fleet(cfg, "vectorized", size_mix=mix)
    return {
        "config": YEAR_SCALE,
        "config_fingerprint": _fingerprint(YEAR_SCALE),
        "engine": "vectorized",
        "jobs": jobs,
        "events": events,
        "wall_s": round(wall, 1),
        "wall_minutes": round(wall / 60.0, 2),
        "events_per_s": round(events / wall, 1),
    }


def _load_committed() -> Dict:
    if BENCH_PATH.exists():
        return json.loads(BENCH_PATH.read_text())
    return {}


def _write(bench: Dict) -> None:
    bench["version"] = 1
    bench["generated_by"] = "benchmarks/fleet_scale.py"
    bench["peak_rss_mb"] = _peak_rss_mb()
    BENCH_PATH.write_text(json.dumps(bench, indent=1, sort_keys=True) + "\n")


def check(fresh_tiny: Dict, committed: Dict) -> None:
    """CI gate: fail when the tiny-config speedup ratio regressed more
    than (1 - REGRESSION_FLOOR) vs the committed baseline."""
    base = committed.get("tiny")
    if not base:
        print("fleet_scale --check: no committed baseline; skipping gate")
        return
    if base.get("config_fingerprint") != fresh_tiny["config_fingerprint"]:
        print("fleet_scale --check: tiny config changed; committed "
              "baseline not comparable — skipping gate (commit a fresh "
              "BENCH_fleet_scale.json)")
        return
    floor = base["speedup"] * REGRESSION_FLOOR
    msg = (f"tiny speedup {fresh_tiny['speedup']:.2f}x vs committed "
           f"{base['speedup']:.2f}x (floor {floor:.2f}x)")
    if fresh_tiny["speedup"] < floor:
        raise SystemExit(f"fleet_scale --check FAILED: {msg}")
    print(f"fleet_scale --check OK: {msg}")


def main(quick: bool = False, tiny: bool = False,
         do_check: bool = False) -> Dict:
    committed = _load_committed()
    bench = dict(committed)
    t_start = time.monotonic()
    fresh_tiny = run_tiny()
    bench["tiny"] = fresh_tiny
    if do_check:
        check(fresh_tiny, committed)
    if not tiny:
        bench["ledger_scale_config"] = run_ledger_scale_config()
        if not quick:
            bench["year_scale"] = run_year_scale()
    _write(bench)
    wall_us = (time.monotonic() - t_start) * 1e6
    derived = {
        "tiny_speedup": bench["tiny"]["speedup"],
        "tiny_events_per_s": bench["tiny"]["vectorized"]["events_per_s"],
    }
    if "ledger_scale_config" in bench:
        derived["ledger_scale_speedup"] = \
            bench["ledger_scale_config"]["speedup"]
    if "year_scale" in bench:
        derived["year_scale_minutes"] = bench["year_scale"]["wall_minutes"]
        derived["year_scale_jobs"] = bench["year_scale"]["jobs"]
    print(f"fleet_scale,{wall_us:.1f},{json.dumps(derived, sort_keys=True)}")
    return bench


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: only the tiny A/B section")
    ap.add_argument("--full", action="store_true",
                    help="include the 1M-job / 1-year run (minutes)")
    ap.add_argument("--check", action="store_true",
                    help="fail if tiny speedup regressed >30%% vs the "
                         "committed BENCH_fleet_scale.json")
    args = ap.parse_args()
    main(quick=not args.full, tiny=args.tiny, do_check=args.check)
