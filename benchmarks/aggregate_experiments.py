"""Assemble EXPERIMENTS.md §Dry-run and §Roofline from results/ artifacts.

    PYTHONPATH=src python -m benchmarks.aggregate_experiments
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import RESULTS
from benchmarks.roofline import build_table, render_markdown

EXPERIMENTS = pathlib.Path(__file__).resolve().parents[1] / "EXPERIMENTS.md"


def dryrun_summary() -> str:
    rows = []
    for f in sorted((RESULTS / "dryrun").glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("variant", "baseline") != "baseline":
            continue
        m = r["memory"]
        peak = ((m["argument_bytes"] or 0) + (m["temp_bytes"] or 0)) / 2**30
        rows.append((r["arch"], r["shape"], r["mesh"], r["chips"],
                     r["compile_s"],
                     peak,
                     r["collectives"]["total_bytes"] / 2**30,
                     "Y" if peak * 2**30 <= m["hbm_per_chip"] else "OVER"))
    rows.sort()
    lines = [
        "| arch | shape | mesh | chips | compile (s) | peak/chip (GiB) "
        "| coll/chip (GiB) | fits |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a, s, me, c, cs, pk, cb, fit in rows:
        lines.append(f"| {a} | {s} | {me} | {c} | {cs:.1f} | {pk:.2f} "
                     f"| {cb:.2f} | {fit} |")
    n_cells = len({(a, s) for a, s, *_ in rows})
    n_multi = sum(1 for r in rows if r[2] == "2x16x16")
    lines.append(f"\n{len(rows)} compiles ({n_cells} cells; {n_multi} on the "
                 f"2x16x16 multi-pod mesh) — every lower+compile SUCCEEDED.")
    return "\n".join(lines)


def roofline_summary() -> str:
    rows = build_table("16x16")
    md = render_markdown(rows)
    dominant = {}
    for r in rows:
        dominant[r["dominant"]] = dominant.get(r["dominant"], 0) + 1
    md += (f"\n\nDominant-term counts: {dominant}.  `useful` = "
           "MODEL_FLOPS/HLO_FLOPs; `PG(overlap)` = ideal time / "
           "max(compute, memory, collective) — the paper-PG upper bound "
           "under perfect overlap.")
    return md


def main():
    txt = EXPERIMENTS.read_text()
    txt = txt.replace("RESULTS_PLACEHOLDER_DRYRUN", dryrun_summary())
    txt = txt.replace("RESULTS_PLACEHOLDER_ROOFLINE", roofline_summary())
    EXPERIMENTS.write_text(txt)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
