"""§Perf hillclimbing driver: hypothesis -> change -> re-lower -> re-analyse.

For a chosen (arch x shape) cell and a list of named variants
(repro.launch.variants), runs the 256-chip dry-run in a subprocess (fresh
process so --xla_force_host_platform_device_count applies), recomputes the
cost reference for the modified config, and appends the roofline terms to
results/perf/<arch>__<shape>.json — the before/after evidence for
EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m benchmarks.perf_iters \
        --cell mixtral-8x7b:train_4k --variants baseline,moe_shard_map
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import subprocess
import sys

from benchmarks.common import RESULTS, emit, save_json
from repro.configs import get_config
from repro.core.costref import cost_reference
from repro.core.flops import model_flops
from repro.core.roofline import make_cell
from repro.launch.variants import apply_variant
from repro.models.config import SHAPES_BY_NAME


def attention_probs_traffic(cfg, shape) -> float:
    """HBM bytes the XLA chunked-attention path spends on score/prob tiles —
    the traffic a fused Pallas flash kernel keeps in VMEM.  fwd + remat-fwd
    + bwd ~ 3 passes; scores fp32 + probs, ~2 tensors per pass."""
    if cfg.family == "ssm":
        return 0.0
    n_attn = sum(1 for i in range(cfg.num_layers) if cfg.is_attention_layer(i))
    if shape.kind == "decode":
        s_rows, s_cols = 1, min(shape.seq_len,
                                cfg.attention_window or shape.seq_len)
        passes = 1.0
    else:
        s_rows = shape.seq_len
        s_cols = (min(shape.seq_len, cfg.attention_window + cfg.attn_chunk)
                  if cfg.attention_window else shape.seq_len)
        passes = 3.0 if (cfg.remat and shape.kind == "train") else 1.0
    per_layer = (shape.global_batch * cfg.num_heads * s_rows * s_cols
                 * (4 + 2))      # fp32 scores + bf16 probs
    return n_attn * per_layer * passes


def run_variant(arch: str, shape_name: str, variant: str,
                skip_dryrun: bool = False) -> dict:
    shape = SHAPES_BY_NAME[shape_name]
    cfg = apply_variant(get_config(arch), variant)

    suffix = "" if variant == "baseline" else f"__{variant}"
    dr_path = (RESULTS / "dryrun" / f"{arch}__{shape_name}__16x16{suffix}.json")
    if not dr_path.exists() and not skip_dryrun:
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape_name, "--single-pod-only",
             "--variant", variant],
            env=env, check=True, timeout=3600,
            cwd=str(pathlib.Path(__file__).resolve().parents[1]))
    rec = json.loads(dr_path.read_text())

    # cost reference: microbatching and the shard_map MoE dispatch don't
    # change single-device model FLOPs — normalize so the cache hits the
    # baseline reference compile
    ref_cfg = dataclasses.replace(cfg, microbatches=1, moe_impl="gspmd",
                                  bf16_grad_reduce=False)
    ref = cost_reference(ref_cfg, shape)

    cell = make_cell(cfg, shape, "16x16", rec["chips"],
                     hlo_flops=ref["flops"], hlo_bytes=ref["bytes"],
                     collective_bytes_per_chip=rec["collectives"]["total_bytes"])
    row = cell.row()
    row["variant"] = variant
    mem = rec["memory"]
    row["peak_gib"] = round(((mem["argument_bytes"] or 0)
                             + (mem["temp_bytes"] or 0)) / 2**30, 2)
    row["fits_hbm"] = row["peak_gib"] * 2**30 <= mem["hbm_per_chip"]
    # flash-kernel memory model: probs tiles stay in VMEM on TPU
    flash_bytes = max(ref["bytes"] - attention_probs_traffic(cfg, shape), 0.0)
    row["t_memory_flash_s"] = flash_bytes / (rec["chips"] * 819e9)
    row["top_collectives"] = rec.get("top_collectives", [])[:3]
    return row


def main(quick: bool = False, cell: str = None, variants: str = None):
    if not cell:
        return None   # driven explicitly via CLI during §Perf
    arch, shape_name = cell.split(":")
    rows = []
    for v in (variants or "baseline").split(","):
        row = run_variant(arch, shape_name, v.strip())
        rows.append(row)
        dom = row["dominant"]
        print(f"{arch} {shape_name} {v:24s} "
              f"t_comp={row['t_compute_s']*1e3:8.2f}ms "
              f"t_mem={row['t_memory_s']*1e3:8.2f}ms "
              f"t_coll={row['t_collective_s']*1e3:8.2f}ms "
              f"dom={dom:10s} peak={row['peak_gib']:6.2f}GiB "
              f"{'FITS' if row['fits_hbm'] else 'OVER'}")
    out_path = RESULTS / "perf" / f"{arch}__{shape_name}.json"
    existing = json.loads(out_path.read_text()) if out_path.exists() else []
    names = {r["variant"] for r in rows}
    existing = [r for r in existing if r.get("variant") not in names]
    save_json(f"perf/{arch}__{shape_name}.json", existing + rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variants", default="baseline")
    a = ap.parse_args()
    main(cell=a.cell, variants=a.variants)
