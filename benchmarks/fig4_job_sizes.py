"""Paper Fig. 4: fleet allocation share by topology size over one year —
the XL share grows as large models take over, stressing the scheduler."""
from __future__ import annotations

from collections import defaultdict

from benchmarks.common import emit, save_json, timed
from repro.fleet.sim import FleetSim, SimConfig
from repro.fleet.workload import (SIZE_MIX_EARLY, SIZE_MIX_LATE,
                                  generate_jobs)


def _mix_at(frac: float):
    return {k: SIZE_MIX_EARLY[k] + frac * (SIZE_MIX_LATE[k] - SIZE_MIX_EARLY[k])
            for k in SIZE_MIX_EARLY}


def run(snapshots: int = 4, seed: int = 4):
    out = []
    for i in range(snapshots):
        mix = _mix_at(i / max(snapshots - 1, 1))
        cfg = SimConfig(n_pods=8, pod_size=256, horizon=14 * 24 * 3600,
                        seed=seed + i)
        sim = FleetSim(cfg)
        for j in generate_jobs(250, cfg.horizon, seed=seed + i,
                               size_mix=mix,
                               capacity_chips=cfg.n_pods * cfg.pod_size):
            sim.submit(j)
        sim.run()
        share = defaultdict(float)
        for iv in sim.intervals:
            if iv.phase.value != "queued":
                share[iv.segment["size_class"]] += iv.chip_time
        total = sum(share.values()) or 1.0
        out.append({k: round(v / total, 4) for k, v in sorted(share.items())})
    return {"allocation_share_by_quarter": out}


def main(quick: bool = False):
    res, us = timed(lambda: run(2 if quick else 4))
    save_json("fleet/fig4_job_sizes.json", res)
    q = res["allocation_share_by_quarter"]
    derived = {"xl_share_first": q[0].get("xl", 0),
               "xl_share_last": q[-1].get("xl", 0),
               "xl_growing": q[-1].get("xl", 0) > q[0].get("xl", 0)}
    emit("fig4_job_sizes", us, derived)
    return res


if __name__ == "__main__":
    print(main())
