"""Scenario x policy sweep: MPG composition under diverse fleet conditions.

The paper's design-space question — "does policy X help under condition
Y?" — as one benchmark: every scenario preset (diurnal load, maintenance
waves, correlated failure storms, heterogeneous generations, compound
stress) crossed with three scheduler policy combinations, each run on a
streaming ledger (no interval retention).  Emits
``results/fleet/scenario_sweep.json``.

    PYTHONPATH=src python -m benchmarks.scenario_sweep           # quick
    PYTHONPATH=src python -m benchmarks.scenario_sweep --full
    PYTHONPATH=src python -m benchmarks.scenario_sweep --tiny    # CI smoke
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit, save_json, timed
from repro.fleet.scenarios import SCENARIOS, build_sim

# (label, placement, preemption, defrag)
POLICY_COMBOS = [
    ("paper", "best_fit", "protect_xl", "drain_for_xl"),
    ("naive", "spread", "priority_only", "none"),
    ("static", "first_fit", "none", "none"),
]

SCALES = {
    # n_jobs, n_pods, pod_size, horizon
    "tiny": dict(n_jobs=24, n_pods=2, pod_size=64, horizon=24 * 3600.0),
    "quick": dict(n_jobs=150, n_pods=4, pod_size=256,
                  horizon=5 * 24 * 3600.0),
    "full": dict(n_jobs=400, n_pods=8, pod_size=256,
                 horizon=14 * 24 * 3600.0),
}


def run(scale: str = "quick", seed: int = 0) -> dict:
    knobs = SCALES[scale]
    rows: dict = {}
    for name in sorted(SCENARIOS):
        rows[name] = {}
        for label, placement, preemption, defrag in POLICY_COMBOS:
            sim = build_sim(SCENARIOS[name], seed=seed,
                            placement=placement, preemption=preemption,
                            defrag=defrag, retain_intervals=False, **knobs)
            sim.run()
            rep = sim.report()
            rows[name][label] = {
                **{k: round(v, 4) for k, v in rep.as_dict().items()},
                "preemptions": sum(j.preemptions
                                   for j in sim.jobs.values()),
                "xl_preemptions": sum(j.preemptions
                                      for j in sim.jobs.values()
                                      if j.spec.size_class == "xl"),
                "failures": sum(j.failures for j in sim.jobs.values()),
                "ledger_events": sim.ledger.n_events,
            }

    checks = {
        "n_scenarios": len(rows),
        "n_policy_combos": len(POLICY_COMBOS),
        "all_bounded": all(0.0 <= row[m] <= 1.0
                           for by_policy in rows.values()
                           for row in by_policy.values()
                           for m in ("SG", "RG", "PG", "MPG")),
        "hetero_lowers_pg": (rows["hetero_fleet"]["paper"]["PG"]
                             < rows["steady"]["paper"]["PG"]),
        "maintenance_lowers_sg": (rows["maintenance"]["paper"]["SG"]
                                  <= rows["steady"]["paper"]["SG"]),
        "storm_lowers_rg": (rows["failure_storm"]["paper"]["RG"]
                            <= rows["steady"]["paper"]["RG"]),
        # structural policy invariants (which combo *wins* on MPG is
        # load-dependent — that's the sweep's data, not a check)
        "protect_xl_never_evicts_xl": all(
            by["paper"]["xl_preemptions"] == 0 for by in rows.values()),
        "static_never_preempts": all(
            by["static"]["preemptions"] == 0 for by in rows.values()),
    }
    return {"scale": scale, "seed": seed,
            "policies": {label: {"placement": p, "preemption": pre,
                                 "defrag": d}
                         for label, p, pre, d in POLICY_COMBOS},
            "scenarios": rows, "checks": checks}


def main(quick: bool = True, scale: str = None):
    scale = scale or ("quick" if quick else "full")
    res, us = timed(lambda: run(scale=scale))
    save_json("fleet/scenario_sweep.json", res)
    emit("scenario_sweep", us, res["checks"])
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI smoke scale")
    ap.add_argument("--full", action="store_true", help="paper scale")
    args = ap.parse_args()
    main(scale="tiny" if args.tiny else ("full" if args.full else "quick"))
